"""Paper Fig. 3 — least squares on USPS(-standin), Hamiltonian network.

Sub-benchmarks (one per sub-figure):
  (a) accuracy vs iterations for mini-batch sizes M in {6, 30, 60, 90}
  (b) test error vs iterations for the same sweep
  (c) accuracy vs communication cost: sI-ADMM vs W-ADMM / D-ADMM / DGD / EXTRA
  (d) test error vs communication cost (same runs)
  (e) running time under straggler delay: coded (cyclic/fractional) vs uncoded
  (f) shortest-path-cycle traversal variant of (c)

Claims validated (EXPERIMENTS.md 'Paper claims'):
  - larger M converges to better accuracy at equal communication (Thm 2),
  - incremental methods dominate gossip baselines in communication,
  - coded schemes' running time is untouched by straggler delay epsilon.
"""

from __future__ import annotations

import numpy as np

from repro.core.admm import ADMMConfig, run_incremental_admm
from repro.core.baselines import run_dadmm, run_dgd, run_extra, run_wadmm
from repro.core.straggler import StragglerModel

from .common import Rows, comm_to_accuracy, setup

ITERS = 1500


def run(rows: Rows) -> dict:
    net, problem = setup("usps")
    out = {}

    # (a)+(b) mini-batch sweep -------------------------------------------
    # (USPS-standin: b=99 rows/agent over K=3 ECNs caps M at 90; the paper
    # plots up to M=300 with a different N — the trend is what's validated)
    for M in (6, 30, 60, 90):
        cfg = ADMMConfig(M=M, K=3, S=0, scheme="uncoded", rho=1.0, c_tau=0.5, c_gamma=1.0)
        tr = rows.timeit(f"fig3ab/sI-ADMM[M={M}]", run_incremental_admm,
                         problem, net, cfg, ITERS, repeats=1)
        out[f"M={M}"] = tr
        rows.add(
            f"fig3ab/sI-ADMM[M={M}]/final", 0.0,
            f"acc={tr.accuracy[-1]:.4f};test_err={tr.test_error[-1]:.4f}",
        )

    # (c)+(d) vs baselines -------------------------------------------------
    cfg = ADMMConfig(M=60, K=3, S=0, scheme="uncoded", rho=1.0, c_tau=0.5, c_gamma=1.0)
    tr_si = out["M=60"]
    tr_w = rows.timeit("fig3cd/W-ADMM", run_wadmm, problem, net, cfg, ITERS, repeats=1)
    tr_da = rows.timeit("fig3cd/D-ADMM", run_dadmm, problem, net, 0.1, ITERS // 10, repeats=1)
    tr_dgd = rows.timeit("fig3cd/DGD", run_dgd, problem, net, 0.05, ITERS // 10, repeats=1)
    tr_ex = rows.timeit("fig3cd/EXTRA", run_extra, problem, net, 0.05, ITERS // 10, repeats=1)
    target = 0.15
    for name, tr in [
        ("sI-ADMM", tr_si), ("W-ADMM", tr_w), ("D-ADMM", tr_da),
        ("DGD", tr_dgd), ("EXTRA", tr_ex),
    ]:
        c = comm_to_accuracy(tr, target)
        rows.add(
            f"fig3cd/{name}/comm_to_acc{target}", 0.0,
            f"comm={c};final_acc={tr.accuracy[-1]:.4f};"
            f"final_test={tr.test_error[-1]:.4f}",
        )
    out.update(wadmm=tr_w, dadmm=tr_da, dgd=tr_dgd, extra=tr_ex)

    # (e) straggler running time ------------------------------------------
    # fractional repetition needs (S+1) | K, so it runs with K=4 ECNs
    # (paper's Fig. 2 cyclic example is exactly K=3, S=1).
    net4, problem4 = setup("usps", K=4)
    for eps in (2e-3, 5e-3, 1e-2):
        strag = StragglerModel(p_straggle=0.3, delay=5e-3, epsilon=eps)
        res = {}
        for label, scheme, S, K, nt, pb in [
            ("uncoded", "uncoded", 0, 3, net, problem),
            ("cyclic", "cyclic", 1, 3, net, problem),
            ("fractional", "fractional", 1, 4, net4, problem4),
        ]:
            M = 60 if K == 3 else 48  # divisible by (S+1)*K
            cfg = ADMMConfig(M=M, K=K, S=S, scheme=scheme,
                             rho=1.0, c_tau=0.5, c_gamma=1.0)
            tr = run_incremental_admm(pb, nt, cfg, ITERS, straggler=strag)
            res[label] = tr
            rows.add(
                f"fig3e/{label}[eps={eps}]", 0.0,
                f"sim_time={tr.sim_time[-1]:.4f}s;acc={tr.accuracy[-1]:.4f}",
            )
        out[f"straggler_eps={eps}"] = res

    # (f) shortest-path cycle ----------------------------------------------
    cfg = ADMMConfig(M=60, K=3, S=0, scheme="uncoded", rho=1.0, c_tau=0.5,
                     c_gamma=1.0, traversal="shortest_path")
    tr = rows.timeit("fig3f/sI-ADMM[shortest_path]", run_incremental_admm,
                     problem, net, cfg, ITERS, repeats=1)
    rows.add(
        "fig3f/sI-ADMM[shortest_path]/final", 0.0,
        f"acc={tr.accuracy[-1]:.4f};comm={tr.comm_cost[-1]:.0f}",
    )
    out["shortest_path"] = tr
    return out
