"""Paper Fig. 3 — least squares on USPS(-standin), Hamiltonian network.

Sub-benchmarks (one per sub-figure):
  (a) accuracy vs iterations for mini-batch sizes M in {6, 30, 60, 90}
  (b) test error vs iterations for the same sweep
  (c) accuracy vs communication cost: sI-ADMM vs W-ADMM / D-ADMM / DGD / EXTRA
  (d) test error vs communication cost (same runs)
  (e) running time under straggler delay: coded (cyclic/fractional) vs uncoded
  (f) shortest-path-cycle traversal variant of (c)

Claims validated (EXPERIMENTS.md 'Paper claims'):
  - larger M converges to better accuracy at equal communication (Thm 2),
  - incremental methods dominate gossip baselines in communication,
  - coded schemes' running time is untouched by straggler delay epsilon.

All sub-figures execute through `repro.experiments` as ONE engine call:
cases sharing a jit static signature (e.g. the M=60 runs of (a), (c) and
(f)) batch into a single vmapped scan (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from repro.experiments import Case, get_sweep, run_sweep

from .common import Rows, comm_to_accuracy

ITERS = 1500


def run(rows: Rows) -> dict:
    # (USPS-standin: b=99 rows/agent over K=3 ECNs caps M at 90; the paper
    # plots up to M=300 with a different N — the trend is what's validated)
    cases = (
        get_sweep("fig3_minibatch", iters=ITERS).cases()
        + get_sweep("fig3_baselines", iters=ITERS).cases()
        + get_sweep("fig3_stragglers", iters=ITERS).cases()
        + [
            Case(
                method="sI-ADMM", dataset="usps", iters=ITERS,
                traversal="shortest_path",
            )
        ]
    )
    cases = list(dict.fromkeys(cases))  # sub-figures share runs; dedupe
    result = run_sweep(cases)
    out = {}

    # (a)+(b) mini-batch sweep -------------------------------------------
    for M in (6, 30, 60, 90):
        tr = result.trace(M=M, method="sI-ADMM", traversal="hamiltonian",
                          S=0, epsilon=1e-2)
        out[f"M={M}"] = tr
        rows.add(
            f"fig3ab/sI-ADMM[M={M}]/final", 0.0,
            f"acc={tr.accuracy[-1]:.4f};test_err={tr.test_error[-1]:.4f}",
        )

    # (c)+(d) vs baselines -------------------------------------------------
    target = 0.15
    for name in ("sI-ADMM", "W-ADMM", "D-ADMM", "DGD", "EXTRA"):
        tr = (
            out["M=60"]
            if name == "sI-ADMM"
            else result.trace(method=name)
        )
        c = comm_to_accuracy(tr, target)
        rows.add(
            f"fig3cd/{name}/comm_to_acc{target}", 0.0,
            f"comm={c};final_acc={tr.accuracy[-1]:.4f};"
            f"final_test={tr.test_error[-1]:.4f}",
        )
        out[name] = tr

    # (e) straggler running time ------------------------------------------
    for eps in (2e-3, 5e-3, 1e-2):
        res = {}
        for label in ("uncoded", "cyclic", "fractional"):
            tr = result.trace(
                method="csI-ADMM", scheme=label, epsilon=eps
            )
            res[label] = tr
            rows.add(
                f"fig3e/{label}[eps={eps}]", 0.0,
                f"sim_time={tr.sim_time[-1]:.4f}s;acc={tr.accuracy[-1]:.4f}",
            )
        out[f"straggler_eps={eps}"] = res

    # (f) shortest-path cycle ----------------------------------------------
    tr = result.trace(traversal="shortest_path")
    rows.add(
        "fig3f/sI-ADMM[shortest_path]/final", 0.0,
        f"acc={tr.accuracy[-1]:.4f};comm={tr.comm_cost[-1]:.0f}",
    )
    out["shortest_path"] = tr

    rows.add(
        "fig3/engine", 0.0,
        f"dispatches={result.n_dispatches};runs={len(result.cases)};"
        f"wall_s={result.wall_s:.2f}",
    )
    return out
