"""Roofline table from dry-run records (EXPERIMENTS.md §Roofline).

Reads the JSONL written by ``repro.launch.dryrun --out`` and renders, per
(arch x shape x mesh): the three roofline terms, the dominant bottleneck,
MODEL_FLOPS / HLO_FLOPS (useful-compute fraction), and per-device memory.

  PYTHONPATH=src python -m benchmarks.roofline results/dryrun.jsonl
"""

from __future__ import annotations

import json
import sys
from typing import List


def load(path: str) -> List[dict]:
    recs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                recs.append(json.loads(line))
    return recs


def fmt_s(v: float) -> str:
    if v >= 1:
        return f"{v:7.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:6.1f}ms"
    return f"{v * 1e6:6.1f}us"


def render_rows(recs: List[dict]) -> List[str]:
    rows = []
    for r in recs:
        if r.get("skipped"):
            rows.append(
                f"| {r['arch']} | {r['shape']} | "
                f"{'multi' if r['multi_pod'] else 'single'} | — | — | — | — | "
                f"skipped: {r['skipped'][:60]}… |"
            )
            continue
        uf = r.get("useful_flop_frac")
        peak = (r.get("memory") or {}).get("peak_bytes")
        peak_gb = f"{peak / 2**30:.1f}" if peak else "—"
        rows.append(
            "| {arch} | {shape} | {mesh} | {c} | {m} | {k} | {b} | "
            "{uf} | {peak} |".format(
                arch=r["arch"],
                shape=r["shape"],
                mesh=("multi" if r["multi_pod"] else "single")
                + ("/" + r["step"] if r["step"].startswith("consensus") else "")
                + (f" [{r['opts']}]" if r.get("opts") else ""),
                c=fmt_s(r["compute_s"]).strip(),
                m=fmt_s(r["memory_s"]).strip(),
                k=fmt_s(r["collective_s"]).strip(),
                b=r["bottleneck"].replace("_s", ""),
                uf=f"{uf:.3f}" if uf else "—",
                peak=peak_gb,
            )
        )
    return rows


def main(argv=None):
    argv = argv if argv is not None else sys.argv[1:]
    if not argv:
        print("usage: python -m benchmarks.roofline <dryrun.jsonl>")
        return 1
    recs = load(argv[0])
    print(
        "| arch | shape | mesh | compute | memory | collective | "
        "bottleneck | useful | peak/dev GB |"
    )
    print("|---|---|---|---|---|---|---|---|---|")
    for row in render_rows(recs):
        print(row)
    # summary: worst useful fraction, most collective-bound
    live = [r for r in recs if not r.get("skipped")]
    if live:
        worst = min(
            (r for r in live if r.get("useful_flop_frac")),
            key=lambda r: r["useful_flop_frac"],
        )
        coll = max(
            live,
            key=lambda r: r["collective_s"]
            / max(r["compute_s"], r["memory_s"], 1e-12),
        )
        print(
            f"\nworst useful-FLOP fraction: {worst['arch']} x {worst['shape']}"
            f" ({worst['useful_flop_frac']:.3f})"
        )
        print(
            f"most collective-bound: {coll['arch']} x {coll['shape']} "
            f"(collective/{coll['bottleneck']} ratio "
            f"{coll['collective_s'] / max(coll['compute_s'], coll['memory_s'], 1e-12):.2f})"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
