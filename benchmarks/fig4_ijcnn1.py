"""Paper Fig. 4 — the Fig. 3 comparisons on the larger ijcnn1(-standin).

Same protocol as fig3 at the paper's larger-data scale: communication
comparison + straggler robustness (the paper reports 'the same performance
can be observed' — this benchmark checks exactly that)."""

from __future__ import annotations

from repro.core.admm import ADMMConfig, run_incremental_admm
from repro.core.baselines import run_dadmm, run_dgd, run_extra, run_wadmm
from repro.core.straggler import StragglerModel

from .common import Rows, comm_to_accuracy, setup

ITERS = 1200


def run(rows: Rows) -> dict:
    net, problem = setup("ijcnn1")
    out = {}

    cfg = ADMMConfig(M=60, K=3, S=0, scheme="uncoded", rho=1.0, c_tau=0.5, c_gamma=1.0)
    tr_si = rows.timeit("fig4/sI-ADMM", run_incremental_admm,
                        problem, net, cfg, ITERS, repeats=1)
    tr_w = rows.timeit("fig4/W-ADMM", run_wadmm, problem, net, cfg, ITERS, repeats=1)
    tr_da = rows.timeit("fig4/D-ADMM", run_dadmm, problem, net, 0.1, ITERS // 10, repeats=1)
    tr_dgd = rows.timeit("fig4/DGD", run_dgd, problem, net, 0.05, ITERS // 10, repeats=1)
    tr_ex = rows.timeit("fig4/EXTRA", run_extra, problem, net, 0.05, ITERS // 10, repeats=1)
    target = 0.15
    for name, tr in [
        ("sI-ADMM", tr_si), ("W-ADMM", tr_w), ("D-ADMM", tr_da),
        ("DGD", tr_dgd), ("EXTRA", tr_ex),
    ]:
        rows.add(
            f"fig4/{name}/comm_to_acc{target}", 0.0,
            f"comm={comm_to_accuracy(tr, target)};"
            f"final_acc={tr.accuracy[-1]:.4f};final_test={tr.test_error[-1]:.4f}",
        )
        out[name] = tr

    strag = StragglerModel(p_straggle=0.3, delay=5e-3, epsilon=1e-2)
    for label, scheme, S in [
        ("uncoded", "uncoded", 0), ("cyclic", "cyclic", 1),
    ]:
        cfg = ADMMConfig(M=60, K=3, S=S, scheme=scheme, rho=1.0, c_tau=0.5, c_gamma=1.0)
        tr = run_incremental_admm(problem, net, cfg, ITERS, straggler=strag)
        rows.add(
            f"fig4/straggler/{label}", 0.0,
            f"sim_time={tr.sim_time[-1]:.4f}s;acc={tr.accuracy[-1]:.4f}",
        )
        out[f"straggler_{label}"] = tr
    return out
