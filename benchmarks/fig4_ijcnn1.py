"""Paper Fig. 4 — the Fig. 3 comparisons on the larger ijcnn1(-standin).

Same protocol as fig3 at the paper's larger-data scale: communication
comparison + straggler robustness (the paper reports 'the same performance
can be observed' — this benchmark checks exactly that).

Runs through `repro.experiments` (one vmapped dispatch per static group;
EXPERIMENTS.md §Perf)."""

from __future__ import annotations

from repro.experiments import get_sweep, run_sweep

from .common import Rows, comm_to_accuracy

ITERS = 1200


def run(rows: Rows) -> dict:
    cases = (
        get_sweep("fig4_baselines", iters=ITERS).cases()
        + get_sweep("fig4_stragglers", iters=ITERS).cases()
    )
    result = run_sweep(cases)
    out = {}

    target = 0.15
    for name in ("sI-ADMM", "W-ADMM", "D-ADMM", "DGD", "EXTRA"):
        tr = result.trace(method=name)
        rows.add(
            f"fig4/{name}/comm_to_acc{target}", 0.0,
            f"comm={comm_to_accuracy(tr, target)};"
            f"final_acc={tr.accuracy[-1]:.4f};final_test={tr.test_error[-1]:.4f}",
        )
        out[name] = tr

    for label in ("uncoded", "cyclic"):
        tr = result.trace(method="csI-ADMM", scheme=label)
        rows.add(
            f"fig4/straggler/{label}", 0.0,
            f"sim_time={tr.sim_time[-1]:.4f}s;acc={tr.accuracy[-1]:.4f}",
        )
        out[f"straggler_{label}"] = tr

    rows.add(
        "fig4/engine", 0.0,
        f"dispatches={result.n_dispatches};runs={len(result.cases)};"
        f"wall_s={result.wall_s:.2f}",
    )
    return out
