"""Paper Fig. 5 — straggler count vs convergence speed (synthetic data).

csI-ADMM with K=6 ECNs and S in {0,...,3}: the allowed batch size is
M_bar = M/(S+1) (eq. 22), so more straggler tolerance => smaller effective
batch => slower convergence (Corollary 2). Averaged over independent runs
like the paper (10 runs there, 4 here for 1-core time).

The whole S x seed grid executes through `repro.experiments`: one vmapped
`lax.scan` (single jit trace + dispatch) per S group instead of a serial
Python loop per (S, seed) pair — serial-vs-vmapped timings in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import numpy as np

from repro.experiments import get_sweep, reduce_mean, run_sweep

from .common import Rows, iters_to_accuracy

ITERS = 1200
RUNS = 4


def run(rows: Rows) -> dict:
    result = run_sweep(get_sweep("fig5", iters=ITERS, runs=RUNS))
    out = {}
    for (S,), red in reduce_mean(result, by=("S",)).items():
        acc = red["mean"]
        speeds = [
            iters_to_accuracy(tr, 0.05) for _, tr in result.select(S=S)
        ]
        M = red["cases"][0].M
        rows.add(
            f"fig5/csI-ADMM[S={S}]", 0.0,
            f"M_bar={M // (S + 1)};iters_to_acc0.05={np.mean(speeds):.0f};"
            f"final_acc={acc[-1]:.5f}",
        )
        out[S] = acc
    rows.add(
        "fig5/engine", 0.0,
        f"dispatches={result.n_dispatches};runs={len(result.cases)};"
        f"wall_s={result.wall_s:.2f}",
    )
    return out
