"""Paper Fig. 5 — straggler count vs convergence speed (synthetic data).

csI-ADMM with K=6 ECNs and S in {0,...,4}: the allowed batch size is
M_bar = M/(S+1) (eq. 22), so more straggler tolerance => smaller effective
batch => slower convergence (Corollary 2). Averaged over independent runs
like the paper (10 runs there, 4 here for 1-core time)."""

from __future__ import annotations

import numpy as np

from repro.core.admm import ADMMConfig, run_incremental_admm
from repro.core.coding import make_code

from .common import Rows, iters_to_accuracy, setup

ITERS = 1200
RUNS = 4
K = 6
M = 360  # divisible by (S+1)*K for S in {0,1,2,3,5}


def run(rows: Rows) -> dict:
    out = {}
    for S in (0, 1, 2, 3):
        accs, speeds = [], []
        for r in range(RUNS):
            net, problem = setup("synthetic", K=K, seed=r)
            # cyclic repetition works for any (K, S); fractional would
            # require (S+1) | K (fails at S=3, K=6)
            cfg = ADMMConfig(
                M=M, K=K, S=S, scheme="cyclic" if S else "uncoded",
                rho=1.0, c_tau=0.5, c_gamma=1.0, seed=r,
            )
            tr = run_incremental_admm(problem, net, cfg, ITERS)
            accs.append(tr.accuracy)
            speeds.append(iters_to_accuracy(tr, 0.05))
        acc = np.mean(accs, axis=0)
        rows.add(
            f"fig5/csI-ADMM[S={S}]", 0.0,
            f"M_bar={M // (S + 1)};iters_to_acc0.05={np.mean(speeds):.0f};"
            f"final_acc={acc[-1]:.5f}",
        )
        out[S] = acc
    return out
