"""Benchmark regression gate for the CI pipeline (DESIGN.md §9).

Compares a fresh ``BENCH_*.json`` (written by ``benchmarks.run --json``)
against the committed ``benchmarks/baseline.json``:

- a sweep's wall-clock may not exceed ``threshold`` x its baseline
  (default 1.5x — generous enough for runner jitter, tight enough to
  catch a lost vmap or a trace-per-case explosion);
- a sweep's dispatch count may not exceed its baseline at all (dispatch
  counts are deterministic grid properties, so ANY growth is a batching
  regression, not noise);
- a sweep's recorded peak RSS (``peak_rss_mb``, the process high-water
  mark after the sweep) may not exceed ``threshold`` x its baseline —
  the O(grid)-memory guarantee of the streaming-reduction layer
  (DESIGN.md §12) is a gated property, not just a design note;
- every baseline sweep must appear in the fresh file — dropping one from
  the Makefile's BENCH_SWEEPS would otherwise silently disable its
  coverage. Remove a sweep deliberately by refreshing the baseline.

Sweeps present only in the FRESH file are reported as NEW and pass, so
adding a sweep to the registry does not require touching the baseline in
the same commit. Refresh the baseline with ``--update`` after a
deliberate change; the recorded wall_s values are the measurement times
``--headroom`` (default 2.5x), absorbing the dev-box-vs-CI-runner speed
gap so the 1.5x gate doesn't flake on slower hardware:

  PYTHONPATH=src python -m benchmarks.run --sweep fig5 --iters 120 \
      --runs 2 --json BENCH_ci.json
  PYTHONPATH=src python -m benchmarks.check BENCH_ci.json
  PYTHONPATH=src python -m benchmarks.check BENCH_ci.json --update
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

BASELINE = pathlib.Path(__file__).resolve().parent / "baseline.json"


def load(path) -> dict:
    with open(path) as fh:
        data = json.load(fh)
    if "sweeps" not in data:
        raise SystemExit(f"{path}: not a benchmarks.run --json file")
    return data


def compare(current: dict, baseline: dict, threshold: float) -> int:
    """Print the comparison table; return the number of regressions."""
    cur, base = current["sweeps"], baseline["sweeps"]
    failures = 0
    print(f"{'sweep':24s} {'base_s':>8s} {'now_s':>8s} {'ratio':>6s} "
          f"{'disp':>9s} {'mem':>6s}  verdict")
    for name in sorted(set(cur) | set(base)):
        if name not in base:
            print(f"{name:24s} {'-':>8s} {cur[name]['wall_s']:8.2f} "
                  f"{'-':>6s} {'-':>9s} {'-':>6s}  NEW (no baseline)")
            continue
        if name not in cur:
            print(f"{name:24s} {base[name]['wall_s']:8.2f} {'-':>8s} "
                  f"{'-':>6s} {'-':>9s} {'-':>6s}  "
                  "FAIL not run (coverage dropped)")
            failures += 1
            continue
        b, c = base[name], cur[name]
        ratio = c["wall_s"] / max(b["wall_s"], 1e-9)
        disp = f"{b['dispatches']}->{c['dispatches']}"
        # Peak-memory gate: skipped when either side predates the
        # peak_rss_mb field (pre-§12 baselines), so old BENCH files keep
        # comparing instead of erroring.
        mem_ratio = None
        if "peak_rss_mb" in b and "peak_rss_mb" in c:
            mem_ratio = c["peak_rss_mb"] / max(b["peak_rss_mb"], 1e-9)
        bad_time = ratio > threshold
        bad_disp = c["dispatches"] > b["dispatches"]
        bad_mem = mem_ratio is not None and mem_ratio > threshold
        verdict = "ok"
        if bad_time:
            verdict = f"FAIL wall-clock > {threshold:.2f}x baseline"
        if bad_mem:
            verdict = f"FAIL peak RSS > {threshold:.2f}x baseline"
        if bad_disp:
            verdict = "FAIL dispatch count grew (batching regression)"
        failures += bad_time + bad_disp + bad_mem
        mem = "-" if mem_ratio is None else f"{mem_ratio:.2f}"
        print(f"{name:24s} {b['wall_s']:8.2f} {c['wall_s']:8.2f} "
              f"{ratio:6.2f} {disp:>9s} {mem:>6s}  {verdict}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench", help="BENCH_*.json produced by "
                    "benchmarks.run --json")
    ap.add_argument("--baseline", default=str(BASELINE),
                    help=f"committed baseline (default {BASELINE})")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="max allowed wall-clock ratio (default 1.5)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the BENCH file "
                    "(wall_s x headroom) instead of checking")
    ap.add_argument("--headroom", type=float, default=2.5,
                    help="--update: factor applied to measured wall_s "
                    "to absorb dev-box-vs-CI-runner speed (default 2.5)")
    ap.add_argument("--mem-headroom", type=float, default=1.3,
                    help="--update: factor applied to measured "
                    "peak_rss_mb (default 1.3 — allocator jitter is far "
                    "smaller than wall-clock jitter)")
    args = ap.parse_args(argv)

    if args.update:
        data = load(args.bench)
        for s in data["sweeps"].values():
            s["wall_s"] = round(s["wall_s"] * args.headroom, 3)
            if "peak_rss_mb" in s:
                s["peak_rss_mb"] = round(
                    s["peak_rss_mb"] * args.mem_headroom, 1
                )
        data["note"] = (
            f"wall_s = measured x {args.headroom} headroom, peak_rss_mb "
            f"= measured x {args.mem_headroom} (benchmarks.check "
            "--update); the 1.5x threshold applies on top. "
            "dispatches/runs are exact grid properties: any dispatch "
            "growth fails the gate regardless of hardware."
        )
        with open(args.baseline, "w") as fh:
            json.dump(data, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"baseline updated from {args.bench} "
              f"(x{args.headroom} headroom)")
        return 0

    current = load(args.bench)
    baseline = load(args.baseline)
    failures = compare(current, baseline, args.threshold)
    if failures:
        print(f"benchmarks.check: {failures} regression(s)")
        return 1
    print("benchmarks.check: no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
