"""Kernel micro-benchmarks: Pallas (interpret mode on CPU) vs jnp oracle.

On CPU, interpret-mode timings measure Python-level kernel-body execution,
NOT TPU performance — the derived column therefore reports the achieved
numerical agreement and the kernel's VMEM working set per grid step, which
ARE meaningful off-TPU. Wall times are recorded for regression tracking
only."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import coded_admm_update, flash_attention, rglru_scan, ssd_scan
from repro.kernels.ref import (
    coded_admm_update_ref,
    flash_attention_ref,
    rglru_scan_ref,
    ssd_scan_ref,
)

from .common import Rows


def run(rows: Rows) -> dict:
    out = {}
    key = jax.random.key(0)

    # coded_admm_update: J=4 messages over a 1M-param model
    J, n = 4, 1 << 20
    ks = jax.random.split(key, 5)
    msgs = jax.random.normal(ks[0], (J, n), jnp.float32)
    coeffs = jax.random.normal(ks[1], (J,), jnp.float32)
    x, y, z = (jax.random.normal(k, (n,), jnp.float32) for k in ks[2:5])
    tau = jnp.asarray(2.0)
    got = rows.timeit(
        "kernels/coded_admm_update[J=4,n=1M]", coded_admm_update,
        msgs, coeffs, x, y, z, tau, 1.0, repeats=2,
    )
    ref = coded_admm_update_ref(msgs, coeffs, x, y, z, tau, 1.0)
    err = float(jnp.abs(got - ref).max())
    vmem = (J + 4) * 4096 * 4 / 1024
    rows.add("kernels/coded_admm_update/check", 0.0,
             f"max_err={err:.2e};vmem_per_step={vmem:.0f}KiB")

    # flash attention: 1k tokens GQA
    B, S, H, KV, hd = 1, 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.bfloat16)
    got = rows.timeit(
        "kernels/flash_attention[1k,GQA4]", flash_attention, q, k, v,
        repeats=1,
    )
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    ).transpose(0, 2, 1, 3)
    err = float(jnp.abs(got.astype(jnp.float32) - ref.astype(jnp.float32)).max())
    vmem = (128 * hd + 2 * 256 * hd + 128 * hd) * 4 / 1024
    rows.add("kernels/flash_attention/check", 0.0,
             f"max_err={err:.2e};vmem_per_step={vmem:.0f}KiB")

    # ssd_scan
    B, S, Hh, P, N = 1, 512, 4, 32, 64
    x_ = jax.random.normal(ks[0], (B, S, Hh, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Hh)))
    A = -jnp.exp(jax.random.normal(ks[2], (Hh,)))
    Bm = jax.random.normal(ks[3], (B, S, N)) / np.sqrt(N)
    Cm = jax.random.normal(ks[4], (B, S, N)) / np.sqrt(N)
    got_y, got_h = rows.timeit(
        "kernels/ssd_scan[512x4x32x64]", ssd_scan, x_, dt, A, Bm, Cm,
        repeats=1,
    )
    ref_y, ref_h = ssd_scan_ref(x_, dt, A, Bm, Cm)
    err = float(jnp.abs(got_y - ref_y).max())
    rows.add("kernels/ssd_scan/check", 0.0, f"max_err={err:.2e}")

    # rglru_scan
    B, S, W = 2, 1024, 256
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W)))
    b = jax.random.normal(ks[1], (B, S, W))
    got_h, got_last = rows.timeit(
        "kernels/rglru_scan[1kx256]", rglru_scan, a, b, repeats=1,
    )
    ref_h, ref_last = rglru_scan_ref(a, b)
    err = float(jnp.abs(got_h - ref_h).max())
    rows.add("kernels/rglru_scan/check", 0.0, f"max_err={err:.2e}")
    return out
