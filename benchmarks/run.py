"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Select subsets with
``--only fig3,fig5``; the roofline table is produced separately from
dry-run records by ``python -m benchmarks.roofline``.

Named sweeps from `repro.experiments.registry` run directly:

  PYTHONPATH=src python -m benchmarks.run --sweep fig5 --out results/fig5.csv
  PYTHONPATH=src python -m benchmarks.run --sweep topology_grid --iters 400 --runs 2
  PYTHONPATH=src python -m benchmarks.run --sweep mesh_scale --mode sharded
  PYTHONPATH=src python -m benchmarks.run --list-sweeps

``--out FILE`` additionally persists the CSV rows (with header) to disk;
``--json FILE`` persists the machine-readable per-sweep engine summary
(wall-clock seconds + dispatch counts) that the benchmark-in-CI pipeline
regression-checks via ``python -m benchmarks.check`` (DESIGN.md §9).
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from .common import Rows, peak_rss_mb

MODULES = ("fig3", "fig4", "fig5", "kernels")


def run_sweeps(names, rows: Rows, iters=None, runs=None, mode=None) -> dict:
    """Run named sweeps; returns {sweep_name: engine summary} for --json."""
    import dataclasses

    from repro.experiments import Case, emit_rows, get_sweep, run_sweep

    kw = {}
    if iters is not None:
        kw["iters"] = iters
    if runs is not None:
        kw["runs"] = runs
    summaries = {}
    for name in names:
        spec = get_sweep(name, **kw)
        result = run_sweep(spec, mode=mode)
        # Reduce over the seed axis; group rows by every Case field that
        # actually varies across the grid (dict-valued axes may touch
        # several fields, so inspect the cases rather than the axis names).
        by = tuple(
            f.name for f in dataclasses.fields(Case)
            if f.name != "seed"
            and len({getattr(c, f.name) for c in result.cases}) > 1
        ) or ("method",)
        # Reduce on the sweep's declared evaluation axis (DESIGN.md §10):
        # the iteration index, or a cumulative field like "sim_time"
        # (accuracy at the shared time budget).
        emit_rows(result, rows, f"sweep/{spec.name}", by, x=spec.x_axis)
        summary = dict(
            wall_s=round(result.wall_s, 3),
            dispatches=result.n_dispatches,
            runs=len(result.cases),
            mode=result.mode,
            n_devices=result.n_devices,
            iters=result.cases[0].iters,
            # Process high-water RSS after this sweep: monotone across
            # sweeps, so the first sweep to raise it is the culprit of a
            # memory regression (gated by benchmarks.check).
            peak_rss_mb=round(peak_rss_mb(), 1),
        )
        summaries[spec.name] = summary
        rows.add(
            f"sweep/{spec.name}/engine", 0.0,
            ";".join(f"{k}={v}" for k, v in summary.items()),
        )
    return summaries


def write_json(path: str, summaries: dict) -> None:
    """BENCH_*.json: engine summaries + enough platform context to judge
    whether a wall-clock comparison is apples-to-apples."""
    import platform

    import jax

    payload = {
        "schema": 1,
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "sweeps": summaries,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only", default=None,
        help=f"comma-separated subset of {MODULES}",
    )
    ap.add_argument(
        "--sweep", default=None,
        help="comma-separated named sweeps from repro.experiments.registry "
        "(skips the figure modules)",
    )
    ap.add_argument("--list-sweeps", action="store_true")
    ap.add_argument("--iters", type=int, default=None,
                    help="override sweep iteration count (smoke runs)")
    ap.add_argument("--runs", type=int, default=None,
                    help="override sweep seed count")
    ap.add_argument("--serial", action="store_true",
                    help="run sweeps through the per-run serial path "
                    "(reference/timing baseline)")
    ap.add_argument("--mode", default=None,
                    choices=("auto", "serial", "batched", "sharded"),
                    help="sweep execution tier (DESIGN.md §9); default "
                    "auto = sharded iff >1 device is visible")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="also write the CSV rows (with header) to FILE")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="write the per-sweep engine summary (wall_s + "
                    "dispatch counts) as JSON for benchmarks.check")
    args = ap.parse_args(argv)
    if args.serial and args.mode not in (None, "serial"):
        ap.error("--serial contradicts --mode " + args.mode)
    if args.json and not args.sweep:
        ap.error("--json requires --sweep (engine summaries)")
    mode = "serial" if args.serial else args.mode

    if args.list_sweeps:
        from repro.experiments import SWEEPS, get_sweep

        for name in sorted(SWEEPS):
            print(f"{name}: {get_sweep(name).description}")
        return 0

    rows = Rows()
    t0 = time.time()
    summaries = {}
    if args.sweep:
        summaries = run_sweeps(
            args.sweep.split(","), rows,
            iters=args.iters, runs=args.runs, mode=mode,
        )
    else:
        selected = args.only.split(",") if args.only else list(MODULES)
        if "fig3" in selected:
            from . import fig3_usps

            fig3_usps.run(rows)
        if "fig4" in selected:
            from . import fig4_ijcnn1

            fig4_ijcnn1.run(rows)
        if "fig5" in selected:
            from . import fig5_stragglers

            fig5_stragglers.run(rows)
        if "kernels" in selected:
            from . import kernels_micro

            kernels_micro.run(rows)

    print(Rows.HEADER)
    rows.emit()
    if args.out:
        rows.write_csv(args.out)
        print(f"# wrote {len(rows.rows)} rows to {args.out}", file=sys.stderr)
    if args.json:
        write_json(args.json, summaries)
        print(
            f"# wrote {len(summaries)} sweep summaries to {args.json}",
            file=sys.stderr,
        )
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
