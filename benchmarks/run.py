"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Select subsets with
``--only fig3,fig5``; the roofline table is produced separately from
dry-run records by ``python -m benchmarks.roofline``.
"""

from __future__ import annotations

import argparse
import sys
import time

from .common import Rows

MODULES = ("fig3", "fig4", "fig5", "kernels")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only", default=None,
        help=f"comma-separated subset of {MODULES}",
    )
    args = ap.parse_args(argv)
    selected = args.only.split(",") if args.only else list(MODULES)

    rows = Rows()
    t0 = time.time()
    if "fig3" in selected:
        from . import fig3_usps

        fig3_usps.run(rows)
    if "fig4" in selected:
        from . import fig4_ijcnn1

        fig4_ijcnn1.run(rows)
    if "fig5" in selected:
        from . import fig5_stragglers

        fig5_stragglers.run(rows)
    if "kernels" in selected:
        from . import kernels_micro

        kernels_micro.run(rows)

    print("name,us_per_call,derived")
    rows.emit()
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
