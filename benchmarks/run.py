"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Select subsets with
``--only fig3,fig5``; the roofline table is produced separately from
dry-run records by ``python -m benchmarks.roofline``.

Named sweeps from `repro.experiments.registry` run directly:

  PYTHONPATH=src python -m benchmarks.run --sweep fig5 --out results/fig5.csv
  PYTHONPATH=src python -m benchmarks.run --sweep topology_grid --iters 400 --runs 2
  PYTHONPATH=src python -m benchmarks.run --sweep privacy_grid,compression_grid
  PYTHONPATH=src python -m benchmarks.run --list-sweeps

``--out FILE`` additionally persists the CSV rows (with header) to disk.
"""

from __future__ import annotations

import argparse
import sys
import time

from .common import Rows

MODULES = ("fig3", "fig4", "fig5", "kernels")


def run_sweeps(names, rows: Rows, iters=None, runs=None, serial=False) -> None:
    import dataclasses

    from repro.experiments import Case, emit_rows, get_sweep, run_sweep

    kw = {}
    if iters is not None:
        kw["iters"] = iters
    if runs is not None:
        kw["runs"] = runs
    for name in names:
        spec = get_sweep(name, **kw)
        result = run_sweep(spec, serial=serial)
        # Reduce over the seed axis; group rows by every Case field that
        # actually varies across the grid (dict-valued axes may touch
        # several fields, so inspect the cases rather than the axis names).
        by = tuple(
            f.name for f in dataclasses.fields(Case)
            if f.name != "seed"
            and len({getattr(c, f.name) for c in result.cases}) > 1
        ) or ("method",)
        emit_rows(result, rows, f"sweep/{spec.name}", by)
        rows.add(
            f"sweep/{spec.name}/engine", 0.0,
            f"dispatches={result.n_dispatches};runs={len(result.cases)};"
            f"wall_s={result.wall_s:.2f};mode={'serial' if serial else 'vmapped'}",
        )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only", default=None,
        help=f"comma-separated subset of {MODULES}",
    )
    ap.add_argument(
        "--sweep", default=None,
        help="comma-separated named sweeps from repro.experiments.registry "
        "(skips the figure modules)",
    )
    ap.add_argument("--list-sweeps", action="store_true")
    ap.add_argument("--iters", type=int, default=None,
                    help="override sweep iteration count (smoke runs)")
    ap.add_argument("--runs", type=int, default=None,
                    help="override sweep seed count")
    ap.add_argument("--serial", action="store_true",
                    help="run sweeps through the per-run serial path "
                    "(reference/timing baseline)")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="also write the CSV rows (with header) to FILE")
    args = ap.parse_args(argv)

    if args.list_sweeps:
        from repro.experiments import SWEEPS, get_sweep

        for name in sorted(SWEEPS):
            print(f"{name}: {get_sweep(name).description}")
        return 0

    rows = Rows()
    t0 = time.time()
    if args.sweep:
        run_sweeps(
            args.sweep.split(","), rows,
            iters=args.iters, runs=args.runs, serial=args.serial,
        )
    else:
        selected = args.only.split(",") if args.only else list(MODULES)
        if "fig3" in selected:
            from . import fig3_usps

            fig3_usps.run(rows)
        if "fig4" in selected:
            from . import fig4_ijcnn1

            fig4_ijcnn1.run(rows)
        if "fig5" in selected:
            from . import fig5_stragglers

            fig5_stragglers.run(rows)
        if "kernels" in selected:
            from . import kernels_micro

            kernels_micro.run(rows)

    print(Rows.HEADER)
    rows.emit()
    if args.out:
        rows.write_csv(args.out)
        print(f"# wrote {len(rows.rows)} rows to {args.out}", file=sys.stderr)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
