"""Shared benchmark plumbing: experiment grid, CSV emission, timers."""

from __future__ import annotations

import os
import resource
import time
from typing import Callable, List, Tuple

import numpy as np

from repro.core.graph import make_network
from repro.core.problems import DATASETS, allocate

# Experiment scale (paper uses a laptop too; these sizes keep each figure
# benchmark under ~a minute on 1 CPU core while preserving every comparison).
N_AGENTS = 10
K_ECNS = 3
CONNECTIVITY = 0.5
SEED = 0


def setup(dataset: str, N: int = N_AGENTS, K: int = K_ECNS, seed: int = SEED):
    net = make_network(N, CONNECTIVITY, seed=seed)
    data = DATASETS[dataset](seed)
    problem = allocate(data, N, K)
    return net, problem


def peak_rss_mb() -> float:
    """Process peak resident set size in MiB (Linux ru_maxrss is KiB).

    A high-water mark, monotone over the process lifetime — so per-sweep
    readings in ``benchmarks.run`` attribute a regression to the first
    sweep that hit the new peak, which is exactly what the check gate
    needs (a later sweep re-reading the same peak adds no signal)."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def iters_to_accuracy(trace, target: float) -> float:
    """First iteration index reaching the accuracy target (eq. 23), or inf."""
    hit = np.nonzero(trace.accuracy <= target)[0]
    return float(hit[0] + 1) if len(hit) else float("inf")


def comm_to_accuracy(trace, target: float) -> float:
    hit = np.nonzero(trace.accuracy <= target)[0]
    return float(trace.comm_cost[hit[0]]) if len(hit) else float("inf")


class Rows:
    """Collects ``name,us_per_call,derived`` CSV rows."""

    HEADER = "name,us_per_call,derived"

    def __init__(self):
        self.rows: List[Tuple[str, float, str]] = []

    def add(self, name: str, us: float, derived: str = ""):
        self.rows.append((name, us, derived))

    def timeit(self, name: str, fn: Callable, *args, repeats: int = 3, **kw):
        fn(*args, **kw)  # warmup / compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = fn(*args, **kw)
        us = (time.perf_counter() - t0) / repeats * 1e6
        self.rows.append((name, us, ""))
        return out

    def emit(self, fh=None):
        """Print rows as CSV to ``fh`` (default stdout), without header."""
        for name, us, derived in self.rows:
            print(f"{name},{us:.1f},{derived}", file=fh)

    def write_csv(self, path: str):
        """Persist header + rows to ``path`` (benchmarks.run --out)."""
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as fh:
            print(self.HEADER, file=fh)
            self.emit(fh)
