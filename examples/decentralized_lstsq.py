"""End-to-end reproduction of the paper's §V experiment in one script.

Runs the full comparison on the USPS-shaped dataset: sI-ADMM (uncoded) and
csI-ADMM (cyclic & fractional) against W-ADMM, D-ADMM, DGD and EXTRA, then
the straggler running-time experiment — and prints the three headline
checks the paper makes:

  1. communication efficiency: incremental ADMM reaches the accuracy
     target with fewer communication units than gossip baselines,
  2. mini-batch effect: larger M converges further at equal iterations,
  3. straggler robustness: coded running time is (nearly) flat in the
     straggler delay cap while uncoded grows with it.

  PYTHONPATH=src python examples/decentralized_lstsq.py
"""

import numpy as np

from repro.core.admm import ADMMConfig, run_incremental_admm
from repro.core.baselines import run_dadmm, run_dgd, run_extra, run_wadmm
from repro.core.graph import make_network
from repro.core.problems import DATASETS, allocate
from repro.core.timing import StragglerModel

N, K, ITERS, TARGET = 10, 3, 1200, 0.15

net = make_network(N, connectivity=0.5, seed=0)
problem = allocate(DATASETS["usps"](0), N, K)


def comm_to(trace, target):
    hit = np.nonzero(trace.accuracy <= target)[0]
    return trace.comm_cost[hit[0]] if len(hit) else float("inf")


# --- 1. communication comparison -----------------------------------------
cfg = ADMMConfig(M=60, K=K, S=0, scheme="uncoded", rho=1.0, c_tau=0.5, c_gamma=1.0)
traces = {
    "sI-ADMM": run_incremental_admm(problem, net, cfg, ITERS),
    "W-ADMM": run_wadmm(problem, net, cfg, ITERS),
    "D-ADMM": run_dadmm(problem, net, 0.1, ITERS // 10),
    "DGD": run_dgd(problem, net, 0.05, ITERS // 10),
    "EXTRA": run_extra(problem, net, 0.05, ITERS // 10),
}
print(f"{'method':10s} {'comm to acc<=' + str(TARGET):>16s} {'final acc':>10s}")
for name, tr in traces.items():
    print(f"{name:10s} {comm_to(tr, TARGET):16.0f} {tr.accuracy[-1]:10.4f}")
assert comm_to(traces["sI-ADMM"], TARGET) < comm_to(traces["D-ADMM"], TARGET)

# --- 2. mini-batch effect --------------------------------------------------
print("\nmini-batch sweep (uncoded sI-ADMM):")
finals = {}
for M in (6, 30, 90):
    cfg = ADMMConfig(M=M, K=K, S=0, scheme="uncoded", rho=1.0, c_tau=0.5, c_gamma=1.0)
    tr = run_incremental_admm(problem, net, cfg, ITERS)
    finals[M] = tr.accuracy[-1]
    print(f"  M={M:3d}: final accuracy {tr.accuracy[-1]:.4f}")
assert finals[90] < finals[6], "larger mini-batch should converge further"

# --- 3. straggler robustness ----------------------------------------------
print("\nstraggler running time (30% straggle prob, delay cap sweep):")
rows = {}
for eps in (2e-3, 1e-2):
    strag = StragglerModel(p_straggle=0.3, delay=5e-3, epsilon=eps)
    for label, scheme, S in (("uncoded", "uncoded", 0), ("csI-ADMM", "cyclic", 1)):
        cfg = ADMMConfig(M=60, K=K, S=S, scheme=scheme, rho=1.0, c_tau=0.5, c_gamma=1.0)
        tr = run_incremental_admm(problem, net, cfg, ITERS, straggler=strag)
        rows[(label, eps)] = tr.sim_time[-1]
        print(f"  {label:9s} eps={eps:.0e}: {tr.sim_time[-1]:6.2f}s "
              f"(acc {tr.accuracy[-1]:.4f})")
uncoded_growth = rows[("uncoded", 1e-2)] / rows[("uncoded", 2e-3)]
coded_growth = rows[("csI-ADMM", 1e-2)] / rows[("csI-ADMM", 2e-3)]
print(f"\nrunning-time growth with 5x delay cap: "
      f"uncoded {uncoded_growth:.2f}x vs coded {coded_growth:.2f}x")
assert coded_growth < uncoded_growth
print("OK — all three §V claims reproduced.")
