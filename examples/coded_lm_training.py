"""csI-ADMM as a *training framework feature*: decentralized LM training.

Two simulated agents with disjoint token streams train a shared transformer
LM by consensus: each agent's mini-batch gradient is computed over K=4
coded ECN partitions (cyclic (4,3) MDS code, S=1 straggler per agent per
step, sampled randomly), and the consensus token z is the served model.

Default is a ~20M-parameter model so the script finishes in minutes on one
CPU core; ``--params 100m`` selects a ~100M-parameter config (the
"train a ~100M model" end-to-end driver — expect ~10s/step on CPU).

  PYTHONPATH=src python examples/coded_lm_training.py --steps 200
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import agent_token_streams, make_lm_batch
from repro.distributed import ConsensusConfig, ConsensusRuntime
from repro.models import ModelConfig, get_model
from repro.models.registry import get_model as _gm  # noqa: F401

SIZES = {
    # ~20M: d=256, L=4, F=1024, vocab=8192
    "20m": dict(d_model=256, n_layers=4, d_ff=1024, vocab=8192,
                n_heads=4, n_kv_heads=2),
    # ~100M: d=640, L=10, F=2560, vocab=50304
    "100m": dict(d_model=640, n_layers=10, d_ff=2560, vocab=50304,
                 n_heads=10, n_kv_heads=5),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", choices=SIZES, default="20m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-rows", type=int, default=2,
                    help="rows per (agent, ecn, partition-copy)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--agents", type=int, default=2)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    s = SIZES[args.params]
    cfg = ModelConfig(
        name=f"consensus-lm-{args.params}", family="dense",
        head_dim=s["d_model"] // s["n_heads"], qk_norm=True,
        dtype="float32", **s,
    )
    model = get_model(cfg)
    print(f"model: {cfg.param_count():,} params "
          f"(d={cfg.d_model}, L={cfg.n_layers}, V={cfg.vocab})")

    A, K, S = args.agents, 4, 1
    # parallel (PW-ADMM-style) mode: every agent commits each step — the
    # beyond-paper variant that actually utilizes a synchronous machine;
    # pass mode="incremental" for the paper-faithful token traversal.
    ccfg = ConsensusConfig(
        n_agents=A, K=K, S=S, scheme="cyclic", mode="parallel",
        rho=1.0, c_tau=1.0, c_gamma=0.05,
    )
    mesh = jax.make_mesh((1, 1, 1), ("agent", "data", "model"))
    rt = ConsensusRuntime(model, ccfg, mesh)
    code = ccfg.code()
    sup = [code.support(j) for j in range(K)]

    state = rt.init_state(jax.random.key(0))
    step = jax.jit(rt.train_step)
    streams = agent_token_streams(A, cfg.vocab, seed=0)
    rng = np.random.default_rng(1)

    losses = []
    for k in range(args.steps):
        # coded allocation: agent a draws K fresh partitions; partition t is
        # laid out on every ECN whose (cyclic) support covers t.
        rows = []
        for a in range(A):
            parts = [make_lm_batch(streams[a], args.batch_rows, args.seq)
                     for _ in range(K)]
            for j in range(K):
                for t in sup[j]:
                    rows.append(parts[t])
        batch = {key: jnp.asarray(np.concatenate([r[key] for r in rows]))
                 for key in rows[0]}
        alive = np.ones((A, K), bool)
        for a in range(A):  # one random straggler per agent per step
            alive[a, rng.integers(K)] = False
        state, metrics = step(state, batch, jnp.asarray(alive))
        losses.append(float(metrics["loss"]))
        if k % args.log_every == 0 or k == args.steps - 1:
            print(f"step {k:4d}  loss {losses[-1]:.4f}  "
                  f"consensus residual {float(metrics['consensus_residual']):.3e}",
                  flush=True)

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nmean loss: first 10 steps {first:.4f} -> last 10 steps {last:.4f}")
    assert last < first, "consensus LM training should reduce the loss"
    print("OK — decentralized coded-gradient LM training converges.")
    return losses


if __name__ == "__main__":
    main()
