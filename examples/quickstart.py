"""Quickstart: coded stochastic incremental ADMM in ~40 lines.

Solves the paper's decentralized least-squares problem (eq. 24) on the
synthetic dataset (Table I) with N=10 agents, K=3 ECNs per agent, and a
(3, 2) cyclic MDS gradient code tolerating S=1 straggler per agent —
exactly the Fig. 2 construction.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.admm import ADMMConfig, run_incremental_admm
from repro.core.graph import make_network
from repro.core.problems import make_synthetic, allocate
from repro.core.timing import StragglerModel

# 1. A connected network of 10 agents (Hamiltonian cycle exists).
net = make_network(N=10, connectivity=0.5, seed=0)

# 2. The paper's synthetic least squares, disjointly allocated: each agent
#    gets b rows, split into K=3 partitions (one per edge-compute node).
problem = allocate(make_synthetic(seed=0), N=10, K=3)

# 3. csI-ADMM: cyclic (K=3, S=1) MDS code — any 2-of-3 ECN responses decode
#    the exact mini-batch gradient (paper Fig. 2), so one straggler per
#    agent never stalls an iteration.
cfg = ADMMConfig(
    M=60,            # mini-batch size (M_bar = M/(S+1) = 30 per eq. 22)
    K=3, S=1, scheme="cyclic",
    rho=1.0, c_tau=0.5, c_gamma=1.0,  # Theorem-2 schedules
)
stragglers = StragglerModel(p_straggle=0.3, delay=5e-3, epsilon=1e-2)

trace = run_incremental_admm(problem, net, cfg, iters=800, straggler=stragglers)

print(f"final accuracy (eq. 23 relative error): {trace.accuracy[-1]:.4f}")
print(f"final test MSE:                         {trace.test_error[-1]:.4f}")
print(f"communication used:                     {trace.comm_cost[-1]:.0f} units")
print(f"simulated wall time:                    {trace.sim_time[-1]:.3f} s")
assert trace.accuracy[-1] < 0.1, "csI-ADMM should converge on this problem"
print("OK — csI-ADMM converged under random stragglers.")
