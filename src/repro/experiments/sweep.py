"""Grid/axes spec -> batched vmapped run -> per-case traces (DESIGN.md §7).

A `Case` pins down ONE run completely: method, dataset, topology, ADMM
hyper-parameters, straggler model, and seed. A `SweepSpec` is a base case
plus named axes; its Cartesian expansion is the grid. `run_sweep` groups
the grid by jit *static signature* (everything that would force a fresh
trace: shapes, K, P, exact_x, iters, method kernel — see
`MethodKernel.static_signature`, DESIGN.md §8) and executes each group
as one `jax.vmap`-ed `lax.scan` — one compile and one device dispatch per
group, however many (seed, config) pairs it contains. With more than one
visible device the vmapped runs axis is additionally laid out over a
1-D mesh (`repro.methods.driver.run_sharded`, DESIGN.md §9); the tier is
picked by ``mode`` ("auto"/"serial"/"batched"/"sharded"). Host-side
sampling (topology, data allocation, straggler times, decode vectors)
stays per-run and is stacked into the batched scan's per-step inputs.

Timing of the serial-vs-batched paths is recorded in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import dataclasses
import itertools
import os
import time
import warnings
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.admm import ADMMConfig, Trace
from repro.core.graph import Network, make_network
from repro.core.problems import DATASETS, LeastSquaresProblem, allocate
from repro.core.timing import TimingModel
from repro.methods import (
    KERNELS,
    Reduction,
    get_kernel,
    run_batch,
    run_serial,
    run_sharded,
)

MODES = ("auto", "serial", "batched", "sharded")

__all__ = ["Case", "SweepSpec", "SweepResult", "run_sweep"]

_cache_enabled = False


def _enable_compilation_cache() -> None:
    """Persistent XLA compilation cache: a sweep's one-trace-per-group
    compile is its dominant cold cost, so repeat benchmark invocations
    load the compiled scan from disk (EXPERIMENTS.md §Perf). Opt out with
    REPRO_JAX_CACHE=0; relocate with REPRO_JAX_CACHE_DIR.
    """
    global _cache_enabled
    if _cache_enabled or os.environ.get("REPRO_JAX_CACHE", "1") == "0":
        return
    _cache_enabled = True
    try:
        jax.config.update(
            "jax_compilation_cache_dir",
            os.environ.get("REPRO_JAX_CACHE_DIR", ".jax_cache"),
        )
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.1)
    except Exception as exc:
        # Older jax without the knobs: compile per process as before — but
        # say so ONCE, so a cold-compile wall-clock regression in CI is
        # explainable from the log instead of silent.
        warnings.warn(
            "persistent XLA compilation cache unavailable "
            f"({type(exc).__name__}: {exc}); sweeps will compile per "
            "process",
            RuntimeWarning,
            stacklevel=2,
        )

# Every registered method kernel is sweepable (DESIGN.md §8).
METHODS = tuple(KERNELS)


@dataclasses.dataclass(frozen=True)
class Case:
    """One fully-specified experiment run (hashable, so grids dedupe)."""

    method: str = "sI-ADMM"  # one of METHODS
    dataset: str = "usps"  # key of repro.core.problems.DATASETS
    N: int = 10  # agents
    K: int = 3  # ECNs per agent
    connectivity: float = 0.5  # eta of make_network
    seed: int = 0  # drives topology, data AND schedule sampling
    iters: int = 1000
    # (c)sI-ADMM hyper-parameters (paper §V defaults)
    rho: float = 1.0
    c_tau: float = 0.5
    c_gamma: float = 1.0
    M: int = 60
    S: int = 0
    scheme: str = "uncoded"
    traversal: str = "hamiltonian"
    # gossip/first-order baseline knobs
    alpha: float = 0.05  # DGD/EXTRA step size; D-ADMM uses `rho`
    # pI-ADMM (privacy) knob
    sigma: float = 0.01  # primal perturbation std at k=1
    # cq-sI-ADMM (compressed token) knobs
    compressor: str = "topk"  # "topk" | "quant"
    frac: float = 0.25  # topk: fraction of token entries kept
    bits: int = 8  # quant: bits per transmitted entry
    # timing model (defaults mirror TimingModel so engine runs match
    # run_incremental_admm(..., straggler=None) if core defaults move)
    p_straggle: float = TimingModel.p_straggle
    delay: float = TimingModel.delay
    epsilon: float = TimingModel.epsilon
    # heterogeneous fleet (DESIGN.md §10): per-worker speed-class factors
    # (assigned round-robin) and the base response distribution
    speed_classes: Tuple[float, ...] = TimingModel.speed_classes
    response: str = TimingModel.response
    # decode deadline for partial-recovery code families (DESIGN.md §11)
    deadline: Optional[float] = TimingModel.deadline
    # event-driven mode (DESIGN.md §13): staleness bound + churn process
    tau_max: float = TimingModel.tau_max
    churn_rate: float = TimingModel.churn_rate
    mttr: float = TimingModel.mttr
    staleness_cap: int = TimingModel.staleness_cap
    # a-csI-ADMM online controller (DESIGN.md §15): the registered arm
    # set — (scheme, S, deadline) frontier cells as a hashable tuple of
    # triples — and the bandit policy selecting among them per step
    arms: Tuple[Tuple[str, int, Optional[float]], ...] = ()
    bandit: str = "ucb1"  # "ucb1" | "exp3"
    bandit_c: float = 0.5  # UCB1 confidence width
    bandit_eta: float = 0.1  # EXP3 learning rate
    bandit_gamma: float = 0.1  # EXP3 exploration mixture

    def admm_config(self) -> ADMMConfig:
        return ADMMConfig(
            rho=self.rho,
            c_tau=self.c_tau,
            c_gamma=self.c_gamma,
            M=self.M,
            K=self.K,
            S=self.S,
            scheme=self.scheme,
            exact_x=self.method == "I-ADMM",
            traversal=self.traversal,
            seed=self.seed,
        )

    def timing_model(self) -> TimingModel:
        return TimingModel(
            p_straggle=self.p_straggle,
            delay=self.delay,
            epsilon=self.epsilon,
            speed_classes=self.speed_classes,
            response=self.response,
            deadline=self.deadline,
            tau_max=self.tau_max,
            churn_rate=self.churn_rate,
            mttr=self.mttr,
            staleness_cap=self.staleness_cap,
        )

    def label(self, *fields: str) -> str:
        """Compact row label, e.g. ``csI-ADMM[S=2,seed=1]``."""
        if not fields:
            fields = ("dataset", "seed")
        kv = ",".join(f"{f}={getattr(self, f)}" for f in fields)
        return f"{self.method}[{kv}]"


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Base case + named axes = a Cartesian experiment grid.

    Axis values are either plain field values (axis name = field name) or
    dicts of several field overrides applied together (axis name is just a
    label), e.g.::

        SweepSpec("fig5", Case(dataset="synthetic", K=6, M=360),
                  axes={"S": [0, 1, 2, 3], "seed": range(4)},
                  fixup=lambda c: dataclasses.replace(
                      c, scheme="cyclic" if c.S else "uncoded"))
    """

    name: str
    base: Case
    axes: Mapping[str, Sequence] = dataclasses.field(default_factory=dict)
    fixup: Optional[Callable[[Case], Case]] = None
    description: str = ""
    # Evaluation axis of the sweep's headline reduction: None = iteration
    # index, or a cumulative Trace field ("sim_time"/"comm_cost") that
    # `reduce_mean`/`emit_rows` resample runs onto (DESIGN.md §10).
    x_axis: Optional[str] = None
    # Streaming in-scan reductions (DESIGN.md §12): when set, run_sweep
    # folds these fixed-size summaries into the scan carry instead of
    # materializing per-iteration Traces — memory O(grid), the fleet-
    # scale path. None keeps the full-Trace default.
    reductions: Optional[Reduction] = None

    def cases(self) -> List[Case]:
        names = list(self.axes)
        cases: List[Case] = []
        seen = set()
        for combo in itertools.product(*(self.axes[n] for n in names)):
            c = self.base
            for name, value in zip(names, combo):
                if isinstance(value, dict):
                    c = dataclasses.replace(c, **value)
                else:
                    c = dataclasses.replace(c, **{name: value})
            if self.fixup is not None:
                c = self.fixup(c)
            if c not in seen:  # fixups may merge grid points; dedupe
                seen.add(c)
                cases.append(c)
        return cases


@dataclasses.dataclass
class SweepResult:
    """Per-case traces + how the grid was batched onto the device(s)."""

    cases: List[Case]
    traces: List[Trace]
    groups: List[Tuple[tuple, int]]  # (static signature, n_runs) per group
    wall_s: float
    mode: str = "batched"  # resolved execution tier (DESIGN.md §9)
    n_devices: int = 1
    # Streaming-sweep output (DESIGN.md §12): flat summary dict keyed
    # "{field}/{stat}", each value a (n_cases, ...) array in grid order.
    # Exactly one of ``traces`` / ``reduced`` is populated.
    reduced: Optional[Dict[str, np.ndarray]] = None

    @property
    def n_dispatches(self) -> int:
        return len(self.groups)

    def trace(self, **filters) -> Trace:
        hits = [
            t
            for c, t in zip(self.cases, self.traces)
            if all(getattr(c, k) == v for k, v in filters.items())
        ]
        if len(hits) != 1:
            raise KeyError(f"{filters} matched {len(hits)} cases, want 1")
        return hits[0]

    def select(self, **filters) -> List[Tuple[Case, Trace]]:
        return [
            (c, t)
            for c, t in zip(self.cases, self.traces)
            if all(getattr(c, k) == v for k, v in filters.items())
        ]


# --------------------------------------------------------------------------
# Case materialization (host-side, cached within one run_sweep call)
# --------------------------------------------------------------------------


def _materialize(
    case: Case,
    net_cache: Dict[tuple, Network],
    prob_cache: Dict[tuple, LeastSquaresProblem],
) -> Tuple[Network, LeastSquaresProblem]:
    if case.dataset not in DATASETS:
        raise KeyError(
            f"unknown dataset {case.dataset!r}; known: {list(DATASETS)}"
        )
    nkey = (case.N, case.connectivity, case.seed)
    net = net_cache.get(nkey)
    if net is None:
        net = net_cache[nkey] = make_network(
            case.N, case.connectivity, seed=case.seed
        )
    pkey = (case.dataset, case.seed, case.N, case.K)
    prob = prob_cache.get(pkey)
    if prob is None:
        prob = prob_cache[pkey] = allocate(
            DATASETS[case.dataset](case.seed), case.N, case.K
        )
    return net, prob


def _signature(case: Case, prob: LeastSquaresProblem) -> tuple:
    """Everything that forces a fresh jit trace: the kernel's static key."""
    kernel = get_kernel(case.method)
    return kernel.static_signature(prob, kernel.config(case), case.iters)


def _dispatch_group(
    method: str,
    cases: List[Case],
    nets: List[Network],
    probs: List[LeastSquaresProblem],
    mode: str,
    reductions: Optional[Reduction] = None,
):
    """Registry lookup + the derived execution backend (DESIGN.md §8, §9).

    Returns the group's per-run `Trace`s — or, with ``reductions``, one
    dict of (group_size, ...) summary arrays (serial runs are stacked
    host-side to the same shape)."""
    kernel = get_kernel(method)
    iters = cases[0].iters
    cfgs = [kernel.config(c) for c in cases]
    if mode == "serial":
        runs = [
            run_serial(kernel, p, n, cf, iters, reductions=reductions)
            for p, n, cf in zip(probs, nets, cfgs)
        ]
        if reductions is None:
            return runs
        return {k: np.stack([r[k] for r in runs]) for k in runs[0]}
    if mode == "sharded":
        return run_sharded(
            kernel, probs, nets, cfgs, iters, reductions=reductions
        )
    return run_batch(kernel, probs, nets, cfgs, iters, reductions=reductions)


def _resolve_mode(serial: bool, mode: Optional[str]) -> str:
    """Execution-tier resolution (DESIGN.md §9): explicit ``mode`` wins,
    the legacy ``serial`` flag maps onto it, REPRO_SWEEP_MODE sets the
    process default, and ``auto`` picks sharded iff >1 device is visible.
    """
    if mode is None:
        mode = "serial" if serial else os.environ.get(
            "REPRO_SWEEP_MODE", "auto"
        )
    elif serial and mode != "serial":
        raise ValueError(f"serial=True contradicts mode={mode!r}")
    if mode not in MODES:
        raise ValueError(f"unknown sweep mode {mode!r}; known: {MODES}")
    if mode == "auto":
        mode = "sharded" if len(jax.devices()) > 1 else "batched"
    return mode


def run_sweep(
    spec_or_cases,
    *,
    serial: bool = False,
    mode: Optional[str] = None,
    verbose: bool = False,
    reductions: Optional[Reduction] = None,
) -> SweepResult:
    """Execute a sweep: one vmapped dispatch per static-signature group.

    Args:
      spec_or_cases: a `SweepSpec` or an explicit list of `Case`s.
      serial: run each case through the per-run (seed) entry points instead
        of the batched ones — the reference path for correctness tests and
        the "before" column of the EXPERIMENTS.md §Perf timing table.
      mode: execution tier — "serial", "batched" (single-device vmap),
        "sharded" (the same vmap laid out over a device mesh on the runs
        axis, DESIGN.md §9), or "auto" (sharded iff >1 device is visible;
        the default, overridable via REPRO_SWEEP_MODE).
      verbose: print one line per dispatched group.
      reductions: a `Reduction` to fold in-scan instead of materializing
        Traces (DESIGN.md §12); defaults to the spec's own ``reductions``
        declaration when a `SweepSpec` is passed. The result then carries
        ``reduced`` (grid-shaped summary arrays) and an empty ``traces``.

    Returns a `SweepResult` with traces (or reduced summaries) in the
    original grid order.
    """
    if reductions is None and isinstance(spec_or_cases, SweepSpec):
        reductions = spec_or_cases.reductions
    cases = (
        spec_or_cases.cases()
        if isinstance(spec_or_cases, SweepSpec)
        else list(spec_or_cases)
    )
    if not cases:
        raise ValueError("empty sweep")
    mode = _resolve_mode(serial, mode)
    _enable_compilation_cache()

    t0 = time.perf_counter()
    net_cache: Dict[tuple, Network] = {}
    prob_cache: Dict[tuple, LeastSquaresProblem] = {}
    mats = [_materialize(c, net_cache, prob_cache) for c in cases]

    # Group by static signature, preserving first-seen order.
    groups: Dict[tuple, List[int]] = {}
    for idx, (case, (_net, prob)) in enumerate(zip(cases, mats)):
        groups.setdefault(_signature(case, prob), []).append(idx)

    traces: List[Optional[Trace]] = [None] * len(cases)
    rows: List[Optional[dict]] = [None] * len(cases)
    group_meta: List[Tuple[tuple, int]] = []
    for sig, idxs in groups.items():
        gcases = [cases[i] for i in idxs]
        gnets = [mats[i][0] for i in idxs]
        gprobs = [mats[i][1] for i in idxs]
        if verbose:
            print(
                f"[sweep] {sig[0]} group x{len(idxs)} ({mode}): {sig[1:]}"
            )
        gout = _dispatch_group(
            gcases[0].method, gcases, gnets, gprobs, mode,
            reductions=reductions,
        )
        if reductions is not None:
            # Scatter the group's (group_size, ...) summary arrays back
            # into grid order; stacked once below.
            for j, i in enumerate(idxs):
                rows[i] = {k: v[j] for k, v in gout.items()}
        else:
            for i, tr in zip(idxs, gout):
                traces[i] = tr
        group_meta.append((sig, len(idxs)))

    reduced = None
    if reductions is not None:
        keys = rows[0].keys()
        if any(r.keys() != keys for r in rows[1:]):
            raise ValueError(
                "sweep groups produced different reduction keys; all "
                "groups must share one Reduction spec"
            )
        reduced = {k: np.stack([r[k] for r in rows]) for k in keys}
        traces = []

    return SweepResult(
        cases=cases,
        traces=traces,  # type: ignore[arg-type]
        groups=group_meta,
        wall_s=time.perf_counter() - t0,
        mode=mode,
        n_devices=len(jax.devices()),
        reduced=reduced,
    )
