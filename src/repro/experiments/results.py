"""Reduction + emission for sweep results (DESIGN.md §7).

Mean/CI over the seed axis (the paper averages Figs. 3-5 over independent
runs) and CSV emission compatible with `benchmarks.common.Rows`.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .sweep import SweepResult

__all__ = ["stack_field", "mean_ci", "reduce_mean", "emit_rows"]


def stack_field(traces: Sequence, field: str) -> np.ndarray:
    """Stack one `Trace` field over runs -> (R, iters)."""
    return np.stack([np.asarray(getattr(t, field)) for t in traces])


def mean_ci(
    values: np.ndarray, axis: int = 0, z: float = 1.96
) -> Tuple[np.ndarray, np.ndarray]:
    """Mean and normal-approximation CI half-width along ``axis``."""
    values = np.asarray(values)
    n = values.shape[axis]
    mean = values.mean(axis=axis)
    if n < 2:
        return mean, np.zeros_like(mean)
    sem = values.std(axis=axis, ddof=1) / np.sqrt(n)
    return mean, z * sem


def reduce_mean(
    result: SweepResult,
    by: Sequence[str],
    field: str = "accuracy",
    z: float = 1.96,
) -> Dict[tuple, dict]:
    """Group cases by the ``by`` fields; mean/CI the rest (the seed axis).

    Returns {key_tuple: {"mean": (iters,), "ci": (iters,), "n": int,
    "cases": [Case, ...]}} with keys ordered by first appearance.
    """
    groups: Dict[tuple, List[int]] = {}
    for i, c in enumerate(result.cases):
        key = tuple(getattr(c, f) for f in by)
        groups.setdefault(key, []).append(i)
    out: Dict[tuple, dict] = {}
    for key, idxs in groups.items():
        stacked = stack_field([result.traces[i] for i in idxs], field)
        mean, ci = mean_ci(stacked, axis=0, z=z)
        out[key] = {
            "mean": mean,
            "ci": ci,
            "n": len(idxs),
            "cases": [result.cases[i] for i in idxs],
        }
    return out


def emit_rows(
    result: SweepResult,
    rows,
    prefix: str,
    by: Sequence[str],
    field: str = "accuracy",
    extra: Optional[dict] = None,
) -> Dict[tuple, dict]:
    """Reduce and append one `benchmarks.common.Rows` row per group.

    Row name is ``{prefix}/{method}[{by=value,...}]``; the derived column
    records the final mean +- CI and the run count. Returns the reduction
    so callers can also plot / post-process.
    """
    red = reduce_mean(result, by, field=field)
    for key, r in red.items():
        case = r["cases"][0]
        kv = ",".join(f"{f}={v}" for f, v in zip(by, key) if f != "method")
        name = f"{prefix}/{case.method}" + (f"[{kv}]" if kv else "")
        derived = (
            f"final_{field}={r['mean'][-1]:.5f};ci={r['ci'][-1]:.5f};"
            f"runs={r['n']}"
        )
        if extra:
            derived += "".join(f";{k}={v}" for k, v in extra.items())
        rows.add(name, 0.0, derived)
    return red
