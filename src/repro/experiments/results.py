"""Reduction + emission for sweep results (DESIGN.md §7, §10).

Mean/CI over the seed axis (the paper averages Figs. 3-5 over independent
runs) and CSV emission compatible with `benchmarks.common.Rows`.

Two reduction axes:

- iteration axis (default): traces align by iteration index, so stacking
  runs is a plain array stack;
- cumulative-cost axis (``x="sim_time"`` or ``x="comm_cost"``): each
  run's clock advances by different amounts per iteration (straggler
  draws, topologies, compressed hops), so runs are first step-resampled
  onto a shared grid (`resample_runs`) — the paper's accuracy-vs-running-
  time comparison (Figs. 3(e), 4) — and the last grid point is the
  accuracy-at-time-budget readout (the budget is the slowest common
  horizon, i.e. the smallest final cumulative cost across the group).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .sweep import SweepResult

__all__ = [
    "stack_field",
    "mean_ci",
    "resample_runs",
    "reduce_mean",
    "emit_rows",
]


def stack_field(traces: Sequence, field: str) -> np.ndarray:
    """Stack one `Trace` field over runs -> (R, iters)."""
    return np.stack([np.asarray(getattr(t, field)) for t in traces])


def _as_float(values: np.ndarray) -> np.ndarray:
    """Promote integer-typed metric arrays (e.g. a unit-count comm_cost)
    to float64 so downstream mean/CI math never runs in integer
    arithmetic; float inputs pass through untouched."""
    values = np.asarray(values)
    if not np.issubdtype(values.dtype, np.floating):
        return values.astype(np.float64)
    return values


def mean_ci(
    values: np.ndarray, axis: int = 0, z: float = 1.96
) -> Tuple[np.ndarray, np.ndarray]:
    """Mean and normal-approximation CI half-width along ``axis``."""
    values = _as_float(values)
    n = values.shape[axis]
    mean = values.mean(axis=axis)
    if n < 2:
        return mean, np.zeros_like(mean)
    sem = values.std(axis=axis, ddof=1) / np.sqrt(n)
    return mean, z * sem


def resample_runs(
    xs: np.ndarray, ys: np.ndarray, n_points: int = 200
) -> Tuple[np.ndarray, np.ndarray]:
    """Step-resample R runs' (cumulative x, metric y) onto a shared grid.

    Args:
      xs: (R, iters) strictly increasing cumulative cost per run
        (sim_time / comm_cost).
      ys: (R, iters) metric recorded at each iteration's completion.
      n_points: grid resolution.

    Returns (grid, values): ``grid`` is (n_points,) from 0 to the
    smallest final cost across runs (so no run is extrapolated), and
    ``values`` is (R, n_points) where values[r, t] is the metric at the
    last iteration run r completed by grid[t] — a right-continuous step
    function. Before a run's first completion the first recorded metric
    is held (the scan records no iteration-0 point). Integer-typed
    metrics are promoted to float (CI math downstream).

    One batched pass instead of a per-run ``np.searchsorted`` loop: for
    each value x[r, j] we find its insertion point into the SHARED grid
    (the dual of searching each grid point into per-run xs — identical
    comparisons, so the result is bit-identical to the loop), histogram
    the insertion points per run with one offset `bincount`, and cumsum
    into "iterations completed by grid[t]" counts.
    """
    xs, ys = np.asarray(xs), _as_float(ys)
    if xs.ndim != 2 or xs.shape != ys.shape:
        raise ValueError(f"xs/ys must be (R, iters), got {xs.shape}")
    R, iters = xs.shape
    grid = np.linspace(0.0, xs[:, -1].min(), n_points)
    # p[r, j] = #{t : grid[t] < xs[r, j]}; values past the grid end land
    # in the extra slot n_points and never enter the cumsum below.
    p = np.searchsorted(grid, xs.ravel(), side="left")
    p += np.repeat(np.arange(R) * (n_points + 1), iters)
    hist = np.bincount(p, minlength=R * (n_points + 1)).reshape(
        R, n_points + 1
    )
    # counts[r, t] = #{j : xs[r, j] <= grid[t]} == the loop's
    # searchsorted(xs[r], grid, "right"); -1 and clip = last completed
    # iteration, held at the first record before any completion.
    counts = np.cumsum(hist[:, :n_points], axis=1)
    idx = np.clip(counts - 1, 0, iters - 1)
    return grid, np.take_along_axis(ys, idx, axis=1)


def reduce_mean(
    result: SweepResult,
    by: Sequence[str],
    field: str = "accuracy",
    z: float = 1.96,
    x: Optional[str] = None,
    n_points: int = 200,
) -> Dict[tuple, dict]:
    """Group cases by the ``by`` fields; mean/CI the rest (the seed axis).

    With ``x`` set to a cumulative Trace field ("sim_time"/"comm_cost"),
    each group's runs are first step-resampled onto a shared grid of
    that axis (`resample_runs`), so the mean is an honest
    accuracy-vs-running-time curve rather than an iteration-index
    average of misaligned clocks.

    Returns {key_tuple: {"mean": (P,), "ci": (P,), "n": int,
    "cases": [Case, ...][, "x": (P,) grid]}} with keys ordered by first
    appearance (P = iters, or n_points when resampled).

    Streamed results (``result.reduced`` set, DESIGN.md §12) reduce the
    pre-summarized grid arrays instead: ``field`` may be a full reduction
    key ("accuracy/at_budget") or a plain metric name (mapped to
    "{field}/final"), and ``x`` is ignored — budget/target axes are
    declared in the `Reduction` spec, so there is nothing to resample.
    """
    groups: Dict[tuple, List[int]] = {}
    for i, c in enumerate(result.cases):
        key = tuple(getattr(c, f) for f in by)
        groups.setdefault(key, []).append(i)
    reduced = getattr(result, "reduced", None)
    if reduced is not None:
        vals = _reduced_field(reduced, field)
        out = {}
        for key, idxs in groups.items():
            entry = {
                "n": len(idxs),
                "cases": [result.cases[i] for i in idxs],
            }
            entry["mean"], entry["ci"] = mean_ci(vals[idxs], axis=0, z=z)
            out[key] = entry
        return out
    out: Dict[tuple, dict] = {}
    for key, idxs in groups.items():
        traces = [result.traces[i] for i in idxs]
        stacked = stack_field(traces, field)
        entry = {"n": len(idxs), "cases": [result.cases[i] for i in idxs]}
        if x is not None:
            grid, stacked = resample_runs(
                stack_field(traces, x), stacked, n_points
            )
            entry["x"] = grid
        entry["mean"], entry["ci"] = mean_ci(stacked, axis=0, z=z)
        out[key] = entry
    return out


def _reduced_field(reduced: Dict[str, np.ndarray], field: str) -> np.ndarray:
    """Resolve a field name against a streamed summary dict: exact key
    first, then the metric's "/final" readout."""
    if field in reduced:
        return reduced[field]
    final = f"{field}/final"
    if final in reduced:
        return reduced[final]
    raise KeyError(
        f"field {field!r} not in the streamed reduction; available: "
        f"{sorted(reduced)}"
    )


def emit_rows(
    result: SweepResult,
    rows,
    prefix: str,
    by: Sequence[str],
    field: str = "accuracy",
    extra: Optional[dict] = None,
    x: Optional[str] = None,
    n_points: int = 200,
) -> Dict[tuple, dict]:
    """Reduce and append one `benchmarks.common.Rows` row per group.

    Row name is ``{prefix}/{method}[{by=value,...}]``; the derived column
    records the final mean +- CI and the run count — on the iteration
    axis by default, or at the shared cumulative budget when ``x`` is a
    cumulative Trace field (accuracy-at-time-budget for x="sim_time").
    Returns the reduction so callers can also plot / post-process.
    """
    red = reduce_mean(result, by, field=field, x=x, n_points=n_points)
    for key, r in red.items():
        case = r["cases"][0]
        kv = ",".join(f"{f}={v}" for f, v in zip(by, key) if f != "method")
        name = f"{prefix}/{case.method}" + (f"[{kv}]" if kv else "")
        # Streamed summaries may be scalar per run (a "/final" readout) or
        # a budget/target vector; the derived column reads the last entry
        # either way, matching the materialized path's final-grid-point
        # convention.
        mean, ci = np.atleast_1d(r["mean"]), np.atleast_1d(r["ci"])
        derived = (
            f"final_{field}={mean[-1]:.5f};ci={ci[-1]:.5f};"
            f"runs={r['n']}"
        )
        if x is not None and "x" in r:
            derived += f";{x}_budget={r['x'][-1]:.5g}"
        if extra:
            derived += "".join(f";{k}={v}" for k, v in extra.items())
        rows.add(name, 0.0, derived)
    return red
