"""Reduction + emission for sweep results (DESIGN.md §7, §10).

Mean/CI over the seed axis (the paper averages Figs. 3-5 over independent
runs) and CSV emission compatible with `benchmarks.common.Rows`.

Two reduction axes:

- iteration axis (default): traces align by iteration index, so stacking
  runs is a plain array stack;
- cumulative-cost axis (``x="sim_time"`` or ``x="comm_cost"``): each
  run's clock advances by different amounts per iteration (straggler
  draws, topologies, compressed hops), so runs are first step-resampled
  onto a shared grid (`resample_runs`) — the paper's accuracy-vs-running-
  time comparison (Figs. 3(e), 4) — and the last grid point is the
  accuracy-at-time-budget readout (the budget is the slowest common
  horizon, i.e. the smallest final cumulative cost across the group).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .sweep import SweepResult

__all__ = [
    "stack_field",
    "mean_ci",
    "resample_runs",
    "reduce_mean",
    "emit_rows",
]


def stack_field(traces: Sequence, field: str) -> np.ndarray:
    """Stack one `Trace` field over runs -> (R, iters)."""
    return np.stack([np.asarray(getattr(t, field)) for t in traces])


def mean_ci(
    values: np.ndarray, axis: int = 0, z: float = 1.96
) -> Tuple[np.ndarray, np.ndarray]:
    """Mean and normal-approximation CI half-width along ``axis``."""
    values = np.asarray(values)
    n = values.shape[axis]
    mean = values.mean(axis=axis)
    if n < 2:
        return mean, np.zeros_like(mean)
    sem = values.std(axis=axis, ddof=1) / np.sqrt(n)
    return mean, z * sem


def resample_runs(
    xs: np.ndarray, ys: np.ndarray, n_points: int = 200
) -> Tuple[np.ndarray, np.ndarray]:
    """Step-resample R runs' (cumulative x, metric y) onto a shared grid.

    Args:
      xs: (R, iters) strictly increasing cumulative cost per run
        (sim_time / comm_cost).
      ys: (R, iters) metric recorded at each iteration's completion.
      n_points: grid resolution.

    Returns (grid, values): ``grid`` is (n_points,) from 0 to the
    smallest final cost across runs (so no run is extrapolated), and
    ``values`` is (R, n_points) where values[r, t] is the metric at the
    last iteration run r completed by grid[t] — a right-continuous step
    function. Before a run's first completion the first recorded metric
    is held (the scan records no iteration-0 point).
    """
    xs, ys = np.asarray(xs), np.asarray(ys)
    if xs.ndim != 2 or xs.shape != ys.shape:
        raise ValueError(f"xs/ys must be (R, iters), got {xs.shape}")
    grid = np.linspace(0.0, xs[:, -1].min(), n_points)
    out = np.empty((xs.shape[0], n_points), dtype=ys.dtype)
    for r in range(xs.shape[0]):
        idx = np.searchsorted(xs[r], grid, side="right") - 1
        out[r] = ys[r][np.clip(idx, 0, xs.shape[1] - 1)]
    return grid, out


def reduce_mean(
    result: SweepResult,
    by: Sequence[str],
    field: str = "accuracy",
    z: float = 1.96,
    x: Optional[str] = None,
    n_points: int = 200,
) -> Dict[tuple, dict]:
    """Group cases by the ``by`` fields; mean/CI the rest (the seed axis).

    With ``x`` set to a cumulative Trace field ("sim_time"/"comm_cost"),
    each group's runs are first step-resampled onto a shared grid of
    that axis (`resample_runs`), so the mean is an honest
    accuracy-vs-running-time curve rather than an iteration-index
    average of misaligned clocks.

    Returns {key_tuple: {"mean": (P,), "ci": (P,), "n": int,
    "cases": [Case, ...][, "x": (P,) grid]}} with keys ordered by first
    appearance (P = iters, or n_points when resampled).
    """
    groups: Dict[tuple, List[int]] = {}
    for i, c in enumerate(result.cases):
        key = tuple(getattr(c, f) for f in by)
        groups.setdefault(key, []).append(i)
    out: Dict[tuple, dict] = {}
    for key, idxs in groups.items():
        traces = [result.traces[i] for i in idxs]
        stacked = stack_field(traces, field)
        entry = {"n": len(idxs), "cases": [result.cases[i] for i in idxs]}
        if x is not None:
            grid, stacked = resample_runs(
                stack_field(traces, x), stacked, n_points
            )
            entry["x"] = grid
        entry["mean"], entry["ci"] = mean_ci(stacked, axis=0, z=z)
        out[key] = entry
    return out


def emit_rows(
    result: SweepResult,
    rows,
    prefix: str,
    by: Sequence[str],
    field: str = "accuracy",
    extra: Optional[dict] = None,
    x: Optional[str] = None,
    n_points: int = 200,
) -> Dict[tuple, dict]:
    """Reduce and append one `benchmarks.common.Rows` row per group.

    Row name is ``{prefix}/{method}[{by=value,...}]``; the derived column
    records the final mean +- CI and the run count — on the iteration
    axis by default, or at the shared cumulative budget when ``x`` is a
    cumulative Trace field (accuracy-at-time-budget for x="sim_time").
    Returns the reduction so callers can also plot / post-process.
    """
    red = reduce_mean(result, by, field=field, x=x, n_points=n_points)
    for key, r in red.items():
        case = r["cases"][0]
        kv = ",".join(f"{f}={v}" for f, v in zip(by, key) if f != "method")
        name = f"{prefix}/{case.method}" + (f"[{kv}]" if kv else "")
        derived = (
            f"final_{field}={r['mean'][-1]:.5f};ci={r['ci'][-1]:.5f};"
            f"runs={r['n']}"
        )
        if x is not None:
            derived += f";{x}_budget={r['x'][-1]:.5g}"
        if extra:
            derived += "".join(f";{k}={v}" for k, v in extra.items())
        rows.add(name, 0.0, derived)
    return red
