"""Batched experiment engine: vmapped multi-seed / multi-config sweeps.

The paper's figures are averages over many independent runs — topology
seeds x straggler tolerances x schemes. This package turns such grids into
first-class objects (DESIGN.md §7):

- :mod:`repro.experiments.sweep` — `Case` (one fully-specified run),
  `SweepSpec` (base case + axes -> Cartesian grid), and `run_sweep`, which
  groups cases by jit static signature and executes each group as a single
  vmapped `lax.scan` (one compile + one dispatch per group instead of one
  serial scan per run).
- :mod:`repro.experiments.registry` — named sweeps for the paper figures
  (fig3/fig4/fig5) and beyond-paper grids (topology x S x scheme).
- :mod:`repro.experiments.results` — mean/CI reduction over sweep axes and
  CSV emission compatible with `benchmarks.common.Rows`.
"""

from repro.methods import Reduction, reduce_trace

from .registry import SWEEPS, get_sweep
from .results import emit_rows, mean_ci, reduce_mean, resample_runs, stack_field
from .sweep import Case, SweepResult, SweepSpec, run_sweep

__all__ = [
    "Case",
    "SweepSpec",
    "SweepResult",
    "run_sweep",
    "Reduction",
    "reduce_trace",
    "SWEEPS",
    "get_sweep",
    "mean_ci",
    "reduce_mean",
    "resample_runs",
    "stack_field",
    "emit_rows",
]
