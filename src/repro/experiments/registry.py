"""Named sweeps: the paper figures + beyond-paper grids (DESIGN.md §7).

Each factory returns a `SweepSpec`; `get_sweep(name, **overrides)` is the
CLI entry used by ``python -m benchmarks.run --sweep <name>``. Scales
default to the benchmark sizes (a minute-ish on one CPU core); pass
``iters=``/``runs=`` overrides for smoke runs.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from repro.methods import Reduction

from .sweep import Case, SweepSpec

__all__ = ["SWEEPS", "get_sweep"]


def _coded_scheme(c: Case) -> Case:
    """S=0 runs uncoded; S>0 keeps the requested coded scheme."""
    return dataclasses.replace(c, scheme="uncoded" if c.S == 0 else c.scheme)


def fig3_minibatch(iters: int = 1500, runs: int = 1) -> SweepSpec:
    """Fig. 3(a)+(b): sI-ADMM mini-batch sweep on USPS(-standin)."""
    return SweepSpec(
        "fig3_minibatch",
        Case(method="sI-ADMM", dataset="usps", iters=iters),
        axes={"M": [6, 30, 60, 90], "seed": list(range(runs))},
        description="accuracy/test-error vs iterations for M in {6,30,60,90}",
    )


def _gossip_iters(c: Case) -> Case:
    """Gossip methods update every agent per iteration — the paper plots
    them at 1/10 the incremental iteration count (equal-work comparison);
    D-ADMM uses rho=0.1, DGD/EXTRA alpha=0.05."""
    if c.method in ("D-ADMM", "DGD", "EXTRA"):
        c = dataclasses.replace(c, iters=max(c.iters // 10, 1), rho=0.1)
    return c


def fig3_baselines(iters: int = 1500, runs: int = 1) -> SweepSpec:
    """Fig. 3(c)+(d): sI-ADMM vs W-ADMM / D-ADMM / DGD / EXTRA on USPS."""
    return SweepSpec(
        "fig3_baselines",
        Case(dataset="usps", iters=iters, alpha=0.05),
        axes={
            "method": ["sI-ADMM", "W-ADMM", "D-ADMM", "DGD", "EXTRA"],
            "seed": list(range(runs)),
        },
        fixup=_gossip_iters,
        description="accuracy vs communication cost, incremental vs gossip",
    )


def fig3_stragglers(iters: int = 1500, runs: int = 1) -> SweepSpec:
    """Fig. 3(e): running time under straggler delay, coded vs uncoded.

    fractional repetition needs (S+1) | K, so it runs with K=4 ECNs
    (M=48 keeps M divisible by (S+1)*K).
    """
    return SweepSpec(
        "fig3_stragglers",
        Case(
            method="csI-ADMM", dataset="usps", iters=iters,
            p_straggle=0.3, delay=5e-3,
        ),
        axes={
            "scheme": [
                {"scheme": "uncoded", "S": 0, "K": 3, "M": 60},
                {"scheme": "cyclic", "S": 1, "K": 3, "M": 60},
                {"scheme": "fractional", "S": 1, "K": 4, "M": 48},
            ],
            "epsilon": [2e-3, 5e-3, 1e-2],
            "seed": list(range(runs)),
        },
        description="sim running time vs max straggler delay epsilon",
    )


def fig4_baselines(iters: int = 1200, runs: int = 1) -> SweepSpec:
    """Fig. 4: the Fig. 3(c)/(d) comparison on ijcnn1(-standin)."""
    return SweepSpec(
        "fig4_baselines",
        Case(dataset="ijcnn1", iters=iters, alpha=0.05),
        axes={
            "method": ["sI-ADMM", "W-ADMM", "D-ADMM", "DGD", "EXTRA"],
            "seed": list(range(runs)),
        },
        fixup=_gossip_iters,
        description="fig3 baseline comparison at ijcnn1 scale",
    )


def fig4_stragglers(iters: int = 1200, runs: int = 1) -> SweepSpec:
    """Fig. 4 straggler pair: uncoded vs cyclic on ijcnn1."""
    return SweepSpec(
        "fig4_stragglers",
        Case(
            method="csI-ADMM", dataset="ijcnn1", iters=iters,
            p_straggle=0.3, delay=5e-3, epsilon=1e-2,
        ),
        axes={
            "scheme": [
                {"scheme": "uncoded", "S": 0},
                {"scheme": "cyclic", "S": 1},
            ],
            "seed": list(range(runs)),
        },
        description="straggler robustness at ijcnn1 scale",
    )


def fig5(iters: int = 1200, runs: int = 4) -> SweepSpec:
    """Fig. 5: straggler tolerance S vs convergence (synthetic, K=6).

    M_bar = M/(S+1) (eq. 22): more tolerance => smaller effective batch =>
    slower convergence (Corollary 2). Cyclic repetition works for any
    (K, S); fractional would require (S+1) | K (fails at S=3, K=6).
    """
    return SweepSpec(
        "fig5",
        Case(
            method="csI-ADMM", dataset="synthetic", K=6, M=360,
            scheme="cyclic", c_tau=0.5, iters=iters,
        ),
        axes={"S": [0, 1, 2, 3], "seed": list(range(runs))},
        fixup=_coded_scheme,
        description="straggler count vs convergence speed, 4-seed average",
    )


def topology_grid(iters: int = 800, runs: int = 3) -> SweepSpec:
    """Beyond-paper: topology connectivity x S x scheme grid (synthetic).

    The paper fixes eta=0.5; this grid crosses sparse/medium/dense
    topologies with straggler tolerance and both repetition schemes in
    one engine call. Shortest-path-cycle traversal makes connectivity
    bite (the Hamiltonian ring is planted identically at every eta; only
    relay hops differ across topologies). Note the two coded schemes
    produce IDENTICAL accuracy curves by construction — both decode the
    exact gradient — and differ in simulated response time and storage
    replication only.
    """
    return SweepSpec(
        "topology_grid",
        Case(
            method="csI-ADMM", dataset="synthetic", K=6, M=360,
            c_tau=0.5, iters=iters, traversal="shortest_path",
        ),
        axes={
            "connectivity": [0.3, 0.6, 0.9],
            "S": [0, 1, 2],
            "scheme": ["cyclic", "fractional"],
            "seed": list(range(runs)),
        },
        fixup=_coded_scheme,
        description="beyond-paper topology x straggler x scheme grid",
    )


def privacy_grid(iters: int = 800, runs: int = 3) -> SweepSpec:
    """Beyond-paper: pI-ADMM privacy noise x straggler tolerance grid.

    Gaussian primal perturbation (arXiv 2003.10615) with std decaying as
    sigma/sqrt(k), crossed with the coded straggler tolerance S — the
    privacy mechanism and the coding layer compose because the kernel
    inherits the full csI-ADMM data path (DESIGN.md §8). sigma=0 is the
    exact sI-/csI-ADMM iterate path (the noise-free control arm).
    """
    return SweepSpec(
        "privacy_grid",
        Case(
            method="pI-ADMM", dataset="usps", K=3, M=60, scheme="cyclic",
            iters=iters,
        ),
        axes={
            "sigma": [0.0, 0.01, 0.05, 0.2],
            "S": [0, 1],
            "seed": list(range(runs)),
        },
        fixup=_coded_scheme,
        description="privacy noise sigma x straggler tolerance S for pI-ADMM",
    )


def compression_grid(iters: int = 800, runs: int = 3) -> SweepSpec:
    """Beyond-paper: cq-sI-ADMM token compression x topology grid.

    Quantized (4/8-bit stochastic) and top-k sparsified token updates
    (arXiv 2501.13516) with error feedback, across sparse/medium/dense
    topologies (shortest-path-cycle traversal, so connectivity bites via
    relay hops — same rationale as `topology_grid`). comm_cost rows
    account compressed hops at their true bit cost including side
    information (top-k indices, quantization sign + scale; see
    `repro.methods.compression`), so accuracy-vs-communication
    comparisons against sI-ADMM are honest.
    """
    return SweepSpec(
        "compression_grid",
        Case(
            method="cq-sI-ADMM", dataset="usps", K=3, M=60, iters=iters,
            traversal="shortest_path",
        ),
        axes={
            "compressor": [
                {"compressor": "quant", "bits": 4},
                {"compressor": "quant", "bits": 8},
                {"compressor": "topk", "frac": 0.25},
            ],
            "connectivity": [0.3, 0.6, 0.9],
            "seed": list(range(runs)),
        },
        description="token compression (bits / top-k) x topology grid",
    )


def _frontier_deadline(c: Case) -> Case:
    """Exact-only families ignore the decode deadline (it is a no-op in
    the schedule), so their deadline grid points merge into one case;
    S=0 points run uncoded as everywhere else."""
    c = _coded_scheme(c)
    if c.scheme != "approx":
        c = dataclasses.replace(c, deadline=None)
    return c


def code_frontier(iters: int = 800, runs: int = 3) -> SweepSpec:
    """Beyond-paper headline: code family x S x decode deadline frontier.

    Every registered exact family (cyclic S+1-replication, MDS full
    replication) against the partial-recovery `approx` family with and
    without a decode deadline (DESIGN.md §11): the deadline trades a
    certified decode error for never waiting past `deadline` seconds on
    a straggling R-th ECN, so the accuracy-vs-sim_time frontier shows
    where bounded-error decoding beats waiting. All axes are host-side
    (decode weights, masks, clocks), so the whole grid is ONE dispatch
    — same static signature as the fig5 family.
    """
    return SweepSpec(
        "code_frontier",
        Case(
            method="csI-ADMM", dataset="synthetic", K=6, M=360,
            scheme="cyclic", c_tau=0.5, iters=iters,
            p_straggle=0.3, delay=5e-3,
        ),
        axes={
            "scheme": ["cyclic", "mds", "approx"],
            "S": [1, 2],
            "deadline": [None, 3e-4, 1e-3],
            "seed": list(range(runs)),
        },
        fixup=_frontier_deadline,
        description="code family x straggler tolerance x decode deadline",
        x_axis="sim_time",
    )


# The code_frontier grid's distinct cells as a controller arm set: the
# exact cyclic family at both straggler tolerances plus the
# partial-recovery family under both decode deadlines (DESIGN.md §15).
# (mds cells are omitted: an exact decode at R responses observes the
# identical response clock as cyclic at equal S — a duplicate arm.)
FRONTIER_ARMS = (
    ("cyclic", 1, None),
    ("cyclic", 2, None),
    ("approx", 1, 3e-4),
    ("approx", 1, 1e-3),
    ("approx", 2, 3e-4),
    ("approx", 2, 1e-3),
)


def adaptive_frontier(iters: int = 800, runs: int = 3) -> SweepSpec:
    """Beyond-paper headline: ONLINE selection over the code_frontier.

    The a-csI-ADMM controller (DESIGN.md §15) runs the exact
    `code_frontier` fleet — same problem, same straggler regime, same
    seeds — but must FIND the best (family, S, deadline) cell from
    observed iteration wall-clock instead of being told: the response
    distribution is hidden from the bandit, which only sees the reward
    of the arm it pulls. Both policies per seed; each policy is one
    static group, so the whole grid is TWO dispatches. Headline gate
    (EXPERIMENTS.md 'Adaptive control'): accuracy-at-time-budget within
    10% of the best fixed cell, strictly better than the worst.
    """
    return SweepSpec(
        "adaptive_frontier",
        Case(
            method="a-csI-ADMM", dataset="synthetic", K=6, M=360,
            scheme="cyclic", c_tau=0.5, iters=iters,
            p_straggle=0.3, delay=5e-3, arms=FRONTIER_ARMS,
            # Tuned on the host replay for THIS fleet's reward gaps
            # (best-vs-second mean-reward gap ~0.01): UCB1's default
            # c=0.5 over-explores 6 close arms; EXP3 needs a hotter
            # learning rate and less forced exploration to separate
            # the top cluster within 800 pulls.
            bandit_c=0.1, bandit_eta=0.15, bandit_gamma=0.05,
        ),
        axes={
            "bandit": ["ucb1", "exp3"],
            "seed": list(range(runs)),
        },
        description="online bandit control over the code/deadline frontier",
        x_axis="sim_time",
    )


def mesh_scale(iters: int = 600, runs: int = 16) -> SweepSpec:
    """Beyond-paper: the fig5 grid at mesh scale (48 runs default — the
    2x2x16 axis product is 64 grid points, but the `_coded_scheme` fixup
    merges the S=0 cyclic/fractional points into one uncoded case).

    Built to saturate a multi-device mesh: S x scheme x 16 seeds is one
    static group, so the whole grid is ONE sharded dispatch whose runs
    axis splits evenly over 1/2/4/8 devices (DESIGN.md §9). The
    benchmark-in-CI pipeline times it via ``benchmarks.run --sweep
    mesh_scale --json`` at smoke scale.
    """
    return SweepSpec(
        "mesh_scale",
        Case(
            method="csI-ADMM", dataset="synthetic", K=6, M=360,
            scheme="cyclic", c_tau=0.5, iters=iters,
        ),
        axes={
            "S": [0, 1],
            "scheme": ["cyclic", "fractional"],
            "seed": list(range(runs)),
        },
        fixup=_coded_scheme,
        description="fig5-style grid sized for mesh-sharded execution",
    )


def fig3e_runtime(iters: int = 1500, runs: int = 2) -> SweepSpec:
    """Fig. 3(e) completed: ALL five fig3 methods on the running-time axis.

    The paper's headline running-time claim compares csI-/sI-ADMM against
    the state-of-the-art baselines; this sweep puts every fig3 method on
    the unified simulated clock (DESIGN.md §10) so
    ``reduce_mean(..., x="sim_time")`` yields the seed-averaged
    accuracy-vs-running-time curves and the accuracy-at-time-budget
    readout (EXPERIMENTS.md 'Running time').
    """
    return SweepSpec(
        "fig3e_runtime",
        Case(
            dataset="usps", iters=iters, alpha=0.05,
            p_straggle=0.3, delay=5e-3,
        ),
        axes={
            "method": ["sI-ADMM", "W-ADMM", "D-ADMM", "DGD", "EXTRA"],
            "seed": list(range(runs)),
        },
        fixup=_gossip_iters,
        description="accuracy vs simulated running time, all fig3 methods",
        x_axis="sim_time",
    )


def hetero_grid(iters: int = 800, runs: int = 3) -> SweepSpec:
    """Beyond-paper: heterogeneous-fleet grid — speed-class mix x S x scheme.

    Shifted-exponential ECN responses (the coded-computing response model,
    arXiv 2107.00481) with per-ECN speed classes assigned round-robin:
    (1.0,) is the paper's homogeneous fleet, (1.0, 2.0) alternates 2x
    slower ECNs, (1.0, 1.0, 4.0) plants one 4x straggler class per
    triple. Crossed with straggler tolerance S and both repetition
    schemes — the regime where coding should pay off most, since slow
    classes are *persistently* slow rather than transiently delayed.
    Speed classes only touch the host-side clock, so the whole grid
    still shares ONE static signature / dispatch.
    """
    return SweepSpec(
        "hetero_grid",
        Case(
            method="csI-ADMM", dataset="synthetic", K=6, M=360,
            scheme="cyclic", c_tau=0.5, iters=iters,
            p_straggle=0.3, delay=5e-3, response="shifted_exp",
        ),
        axes={
            "speed_classes": [(1.0,), (1.0, 2.0), (1.0, 1.0, 4.0)],
            "S": [0, 1, 2],
            "scheme": ["cyclic", "fractional"],
            "seed": list(range(runs)),
        },
        fixup=_coded_scheme,
        description="ECN speed-class mix x straggler tolerance x scheme",
        x_axis="sim_time",
    )


def fleet_frontier(iters: int = 1000, runs: int = 1000) -> SweepSpec:
    """Fleet-scale headline: heavy-tailed fleets x code family x S (§12).

    The regime the streaming-reduction layer exists for: thousands of
    independent straggler realizations (2 response tails x 3 code
    families x 2 tolerances x ``runs`` seeds = 12 x runs grid points) at
    agent populations where materializing per-iteration Traces would be
    O(iters x runs) memory. The declared `Reduction` keeps everything
    the frontier needs — accuracy/test-error at sim-time budgets,
    time-to-accuracy targets, trajectory quantiles — in O(grid) memory,
    so the default grid (12,000 runs) executes in a handful of sharded
    dispatches under REPRO_SHARD_MEM_MB (EXPERIMENTS.md 'Fleet scale').
    Lognormal vs Pareto base responses (finite vs infinite variance)
    with a planted 4x speed class, against cyclic/MDS exact decoding and
    the deadline-truncated approximate family (DESIGN.md §11).
    """
    return SweepSpec(
        "fleet_frontier",
        Case(
            method="csI-ADMM", dataset="synthetic", K=6, M=360,
            scheme="cyclic", c_tau=0.5, iters=iters,
            p_straggle=0.3, delay=5e-3, speed_classes=(1.0, 1.0, 4.0),
        ),
        axes={
            "response": ["lognormal", "pareto"],
            "scheme": [
                {"scheme": "cyclic"},
                {"scheme": "mds"},
                {"scheme": "approx", "deadline": 3e-4},
            ],
            "S": [1, 2],
            "seed": list(range(runs)),
        },
        description="heavy-tailed fleet x code family x S, streaming "
        "reductions at fleet scale",
        x_axis="sim_time",
        reductions=Reduction(
            fields=("accuracy", "test_error"),
            budgets=(0.25, 0.5, 1.0, 2.0),
            x="sim_time",
            targets=(0.5, 0.2, 0.1),
            quantiles=(0.1, 0.5, 0.9),
        ),
    )


def staleness_frontier(iters: int = 800, runs: int = 2) -> SweepSpec:
    """Event-driven headline: convergence vs staleness bound x method (§13).

    csI-ADMM's token and the gossip methods' broadcasts land with a
    bounded simulated delay tau ~ U(0, tau_max]; tau_max = 0 is the
    bulk-synchronous control arm and stays bit-identical to the
    pre-async sweeps (it keeps the synchronous static signature, so
    each method contributes exactly two dispatch groups: one sync, one
    async ring). All schedules are host-side scan inputs — the whole
    async half of the grid per method is ONE trace however many
    tau_max values it spans.
    """
    return SweepSpec(
        "staleness_frontier",
        Case(
            method="csI-ADMM", dataset="usps", K=3, M=60, scheme="cyclic",
            S=1, alpha=0.05, iters=iters, p_straggle=0.3, delay=5e-3,
        ),
        axes={
            "method": ["csI-ADMM", "D-ADMM", "DGD", "EXTRA"],
            "tau_max": [0.0, 5e-4, 2e-3, 8e-3],
            "seed": list(range(runs)),
        },
        fixup=_gossip_iters,
        description="staleness bound tau_max x method, sync arm bit-exact",
        x_axis="sim_time",
    )


def churn_grid(iters: int = 800, runs: int = 3) -> SweepSpec:
    """Event-driven headline: accuracy under churn rate x code family (§13).

    Agents and ECNs crash/recover as an alternating-renewal process
    (mean uptime 1/churn_rate, mean repair mttr); crashed ECNs are
    censored from the alive mask before decode, so each family's
    decodable-pattern set is what is being stress-tested: cyclic decodes
    only contiguous-ish R-subsets, MDS any R survivors, and the approx
    family's deadline decode degrades gracefully below R. churn_rate = 0
    is the synchronous control arm (bit-identical path).
    """
    return SweepSpec(
        "churn_grid",
        Case(
            method="csI-ADMM", dataset="synthetic", K=6, M=360, S=2,
            scheme="cyclic", c_tau=0.5, iters=iters,
            p_straggle=0.3, delay=5e-3, mttr=0.05,
        ),
        axes={
            "scheme": [
                {"scheme": "cyclic"},
                {"scheme": "mds"},
                {"scheme": "approx", "deadline": 3e-4},
            ],
            "churn_rate": [0.0, 5.0, 25.0],
            "seed": list(range(runs)),
        },
        description="churn rate x code family under elastic-fleet decode",
        x_axis="sim_time",
    )


SWEEPS: Dict[str, Callable[..., SweepSpec]] = {
    "fig3_minibatch": fig3_minibatch,
    "fig3_baselines": fig3_baselines,
    "fig3_stragglers": fig3_stragglers,
    "fig3e_runtime": fig3e_runtime,
    "fig4_baselines": fig4_baselines,
    "fig4_stragglers": fig4_stragglers,
    "fig5": fig5,
    "topology_grid": topology_grid,
    "privacy_grid": privacy_grid,
    "code_frontier": code_frontier,
    "adaptive_frontier": adaptive_frontier,
    "compression_grid": compression_grid,
    "hetero_grid": hetero_grid,
    "mesh_scale": mesh_scale,
    "fleet_frontier": fleet_frontier,
    "staleness_frontier": staleness_frontier,
    "churn_grid": churn_grid,
}


def get_sweep(name: str, **overrides) -> SweepSpec:
    """Look up a named sweep; ``overrides`` go to the factory (iters/runs)."""
    if name not in SWEEPS:
        raise KeyError(f"unknown sweep {name!r}; known: {sorted(SWEEPS)}")
    return SWEEPS[name](**overrides)
