"""Blocked (flash) attention Pallas TPU kernel: causal, sliding-window, GQA.

Grid (B, H, nq, nk), nk innermost; online-softmax accumulators (acc, m, l)
live in VMEM scratch and persist across the nk sweep. GQA is handled in the
K/V BlockSpec index maps (query head h reads kv head h*KV//H), so grouped
K/V are never materialized at H width — on TPU this keeps the K/V HBM
traffic at KV/H of the expanded version.

Blocks fully outside the causal/window band contribute nothing: the kernel
still visits them (TPU grids are static) but skips the matmuls under
``pl.when``, so the MXU work matches the band's true FLOP count.

Block shapes: (block_q, hd) and (block_kv, hd) tiles — hd is 64/128 in every
assigned config and block sizes default to 128/256, all lane-aligned.
VMEM: q + k + v + acc ≈ (bq + 2·bkv + bq)·hd·4B ≈ 0.5 MB at defaults.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel"]

NEG_INF = -1e30


def _body(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_kv: int,
    n_kv: int,
    q_offset: int,
    sm_scale: float,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Block-level band test (static offsets, dynamic block ids).
    q_lo = qi * block_q + q_offset  # first query position in block
    q_hi = q_lo + block_q - 1
    k_lo = ki * block_kv
    k_hi = k_lo + block_kv - 1
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_hi
    if window is not None:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = q @ k.T  # (bq, bkv)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...][:, 0]  # (bq,)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = (l_ref[...][:, 0] * alpha + p.sum(axis=-1))[:, None]
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new[:, None]

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)  # (bq, 1)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, KV, Skv, hd)
    v: jax.Array,  # (B, KV, Skv, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    assert Sq % block_q == 0 and Skv % block_kv == 0, (Sq, Skv, block_q, block_kv)
    nq, nk = Sq // block_q, Skv // block_kv
    grid = (B, H, nq, nk)

    def kv_of(h):
        return h * KV // H

    body = functools.partial(
        _body,
        causal=causal,
        window=window,
        block_q=block_q,
        block_kv=block_kv,
        n_kv=nk,
        q_offset=q_offset,
        sm_scale=1.0 / (hd**0.5),
    )
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, hd), lambda b, h, i, j: (b, kv_of(h), j, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_kv, hd), lambda b, h, i, j: (b, kv_of(h), j, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),  # acc
            pltpu.VMEM((block_q, 1), jnp.float32),  # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),  # l (running denom)
        ],
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
