"""Chunked SSD (Mamba-2 state-space duality) Pallas TPU kernel.

One grid step processes one (batch, head, chunk) tile. The chunk index is
the innermost grid dimension, so the (P, N) SSM state carried in VMEM
scratch flows chunk-to-chunk exactly like the `lax.scan` in the reference —
but the intra-chunk quadratic form runs on the MXU from VMEM-resident tiles:

  y_intra = (C B^T ∘ L) (x·dt)       (Q,Q)x(Q,P) matmuls
  y_inter = (C h^T) ∘ exp(cum)       state broadcast
  h'      = h·exp(cum_Q) + (x·dt)^T (B ∘ decay)   (P,Q)x(Q,N)

Q = chunk (default 128) keeps the (Q,Q) dual form small; VMEM per step is
Q·(P + 2N + H-slice) + P·N floats ≈ 0.4 MB at Q=128, P=64, N=128.

The decay/cumsum algebra is done in f32 (exp of sums of negatives), the
matmuls accumulate in f32 — matching ref.py bit-for-bit semantics up to
associativity.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_kernel"]


def _segsum(a: jax.Array) -> jax.Array:
    """out[i, j] = sum_{j < t <= i} a[t]; -inf above diagonal. a: (Q,)."""
    Q = a.shape[0]
    cs = jnp.cumsum(a)
    diff = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    return jnp.where(jj <= ii, diff, -jnp.inf)


def _body(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, hout_ref, h_ref, *, n_chunks: int):
    # NOTE kernel signature order: inputs, outputs, then scratch (h_ref).
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    A = A_ref[0, 0]  # scalar (this head's decay rate)
    Bm = B_ref[0].astype(jnp.float32)  # (Q, N)
    Cm = C_ref[0].astype(jnp.float32)  # (Q, N)
    h = h_ref[...]  # (P, N) f32

    a = dt * A  # (Q,) log-decay
    cum = jnp.cumsum(a)  # (Q,)
    xdt = x * dt[:, None]  # (Q, P)

    # Intra-chunk dual quadratic form.
    L = jnp.exp(_segsum(a))  # (Q, Q) lower-triangular decay
    scores = Cm @ Bm.T  # (Q, Q)
    y_intra = (scores * L) @ xdt  # (Q, P)
    # Carried-state contribution.
    y_inter = (Cm @ h.T) * jnp.exp(cum)[:, None]  # (Q, P)
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # Chunk-final state: h' = h e^{cum_Q} + sum_j e^{cum_Q - cum_j} x_j B_j^T.
    decay_out = jnp.exp(cum[-1] - cum)  # (Q,)
    h_ref[...] = h * jnp.exp(cum[-1]) + xdt.T @ (Bm * decay_out[:, None])

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hout_ref[0, 0] = h_ref[...]


def ssd_scan_kernel(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) f32 (post-softplus)
    A: jax.Array,  # (H,) f32 (negative)
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P) f32, h_final (B,H,P,N) f32), zero initial state."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    grid = (B, H, nc)
    body = functools.partial(_body, n_chunks=nc)
    y, h_fin = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
        name="ssd_scan",
    )(x, dt, A.reshape(H, 1), Bm, Cm)
    return y, h_fin
