"""Fused MDS decode-combine (+ ADMM x-update) Pallas TPU kernel.

The csI-ADMM hot spot on the agent: combine the J coded gradient messages
with the decode vector a (eq. 6, `q_dec`), then apply the proximal
linearized x-update (eq. 5a). Unfused, that is J + 4 HBM passes over
n = |params| floats; fused it is one read of (J+3)·n and one write of n —
strictly memory-bound, so the win is exactly the eliminated passes.

Tiling: grid over n in ``block_n`` chunks; each step holds a (J, block_n)
tile of messages plus (1, block_n) tiles of x/y/z in VMEM. J is tiny (= K
ECNs, 3..16) so VMEM footprint ~ (J+4)·block_n·4B — block_n = 16384 at
J = 16 is ~1.3 MB, well inside the ~16 MB/core budget, and the last-dim
tile is a multiple of 128 lanes.

Both kernels take a runtime (J,) ``mask`` alongside the decode
coefficients (DESIGN.md §11): dead message rows are hard-zeroed with a
``where`` BEFORE the weighted reduction, so garbage in never-arrived
rows — including NaN/Inf, which ``0 * NaN`` would propagate — cannot
pollute the decode. Coefficients and mask are data, not statics: every
straggler pattern and deadline truncation of a sweep reuses ONE trace.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["coded_combine_kernel", "coded_admm_update_kernel"]

DEFAULT_BLOCK_N = 16_384


def _compute_dtype(dtype) -> jnp.dtype:
    """Accumulate in >= float32: bf16 promotes to f32 (TPU MXU/VPU native),
    f64 stays f64 so the x64 convergence suite keeps full precision when
    the kernel runs in interpret mode on CPU."""
    return jnp.promote_types(dtype, jnp.float32)


def _masked(msgs_ref, mask_ref, ct):
    """Dead rows -> exact zeros via where (NaN-safe, unlike 0 * NaN)."""
    return jnp.where(
        mask_ref[...].astype(jnp.float32) > 0.0,
        msgs_ref[...].astype(ct),
        jnp.zeros((), ct),
    )


def _combine_body(msgs_ref, coeffs_ref, mask_ref, out_ref):
    ct = _compute_dtype(msgs_ref.dtype)
    m = _masked(msgs_ref, mask_ref, ct)  # (J, bn)
    c = coeffs_ref[...].astype(ct)  # (J, 1)
    out_ref[...] = jnp.sum(m * c, axis=0, keepdims=True).astype(out_ref.dtype)


def coded_combine_kernel(
    msgs: jax.Array,  # (J, n) — n a multiple of block_n (ops.py pads)
    coeffs: jax.Array,  # (J,)
    mask: jax.Array,  # (J,) >0 = row alive
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jax.Array:
    """out (n,) = sum_j coeffs[j] * mask[j]>0 * msgs[j], acc in >= f32."""
    J, n = msgs.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    col = pl.BlockSpec((J, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        _combine_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((J, block_n), lambda i: (0, i)),
            col,
            col,
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), _compute_dtype(msgs.dtype)),
        interpret=interpret,
        name="coded_combine",
    )(msgs, coeffs.reshape(J, 1), mask.reshape(J, 1))
    return out[0]


def _admm_body(
    msgs_ref, coeffs_ref, mask_ref, x_ref, y_ref, z_ref, scal_ref, out_ref
):
    ct = _compute_dtype(x_ref.dtype)
    m = _masked(msgs_ref, mask_ref, ct)  # (J, bn)
    c = coeffs_ref[...].astype(ct)  # (J, 1)
    G = jnp.sum(m * c, axis=0, keepdims=True)  # (1, bn)
    tau = scal_ref[0, 0].astype(ct)
    rho = scal_ref[0, 1].astype(ct)
    num = (
        tau * x_ref[...].astype(ct)
        + rho * z_ref[...].astype(ct)
        + y_ref[...].astype(ct)
        - G
    )
    out_ref[...] = (num / (rho + tau)).astype(out_ref.dtype)


def coded_admm_update_kernel(
    msgs: jax.Array,  # (J, n)
    coeffs: jax.Array,  # (J,)
    mask: jax.Array,  # (J,) >0 = row alive
    x: jax.Array,  # (n,)
    y: jax.Array,  # (n,)
    z: jax.Array,  # (n,)
    tau: jax.Array,  # scalar
    rho: float,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jax.Array:
    """Fused decode + eq. (5a): x+ = (tau x + rho z + y - a.msgs)/(rho+tau).

    ``tau`` and ``rho`` may be traced scalars (the method step passes the
    per-iteration schedule value); both ride in via the (1, 2) scal tile.
    ``mask`` hard-zeroes dead message rows before the reduction.
    """
    J, n = msgs.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    st = _compute_dtype(x.dtype)
    scal = jnp.stack(
        [jnp.asarray(tau, st), jnp.asarray(rho, st)]
    ).reshape(1, 2)
    row = pl.BlockSpec((1, block_n), lambda i: (0, i))
    col = pl.BlockSpec((J, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        _admm_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((J, block_n), lambda i: (0, i)),
            col,
            col,
            row,
            row,
            row,
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((1, n), x.dtype),
        interpret=interpret,
        name="coded_admm_update",
    )(msgs, coeffs.reshape(J, 1), mask.reshape(J, 1), x[None], y[None],
      z[None], scal)
    return out[0]
