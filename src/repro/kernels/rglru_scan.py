"""RG-LRU linear recurrence Pallas TPU kernel (h_t = a_t h_{t-1} + b_t).

Grid (B, nw, ns) — sequence blocks innermost so the (1, block_w) state row
carried in VMEM scratch flows block-to-block; channels are tiled in
``block_w`` lanes so arbitrarily wide recurrences fit VMEM. Inside a block
the recurrence runs as a log-depth Blelloch-style doubling scan over the
(block_s, block_w) tile — VPU element-wise ops on lane-aligned rows — rather
than a step-per-element loop: positions advance by strides 1,2,4,... so a
256-step block costs 8 vector passes instead of 256 scalar-indexed steps.

The gate computation (two sigmoids + matmuls) stays outside in XLA: it is
MXU-friendly batched GEMM and fuses into the surrounding projections; the
kernel takes the precomputed (a, b) pair, which is what makes it a pure
bandwidth-bound scan (2 reads + 1 write per element).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rglru_scan_kernel"]


def _body(a_ref, b_ref, h_ref, hlast_ref, carry_ref, *, block_s: int, n_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0].astype(jnp.float32)  # (bs, bw)
    b = b_ref[0].astype(jnp.float32)  # (bs, bw)
    h0 = carry_ref[...]  # (1, bw)

    # Fold carried state into step 0, then a doubling (Hillis-Steele) scan
    # over the composition (a1,b1)∘(a2,b2) = (a1·a2, a2·b1 + b2).
    b = b.at[0].add(a[0] * h0[0])
    steps = max(block_s.bit_length() - 1, 0)  # log2(block_s)
    stride = 1
    for _ in range(steps):
        a_prev = jnp.pad(a, ((stride, 0), (0, 0)), constant_values=1.0)[
            :block_s
        ]
        b_prev = jnp.pad(b, ((stride, 0), (0, 0)))[:block_s]
        b = a * b_prev + b
        a = a * a_prev
        stride *= 2

    h_ref[0] = b.astype(h_ref.dtype)  # b now holds the inclusive scan h_t
    carry_ref[...] = b[-1:].astype(jnp.float32)

    @pl.when(si == n_s - 1)
    def _emit():
        hlast_ref[...] = carry_ref[...]


def rglru_scan_kernel(
    a: jax.Array,  # (B, S, W) decay factors
    b: jax.Array,  # (B, S, W) input terms
    *,
    block_s: int = 256,
    block_w: int = 512,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (h (B,S,W) f32, h_last (B,W) f32); zero initial state.

    block_s must be a power of two (doubling scan).
    """
    B, S, W = a.shape
    assert S % block_s == 0, (S, block_s)
    assert block_s & (block_s - 1) == 0, f"block_s={block_s} not a power of 2"
    bw = min(block_w, W)
    assert W % bw == 0, (W, bw)
    grid = (B, W // bw, S // block_s)
    body = functools.partial(_body, block_s=block_s, n_s=S // block_s)
    h, hlast = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, block_s, bw), lambda bi, wi, si: (bi, si, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, bw), lambda bi, wi, si: (bi, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
        name="rglru_scan",
    )(a, b)
    return h, hlast
