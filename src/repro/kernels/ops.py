"""Jitted public wrappers around the Pallas kernels.

Each op pads/reshapes to kernel-legal tiles, dispatches the kernel
(interpret=True automatically off-TPU so the same call sites work in this
CPU container), and restores the caller's layout. These are the functions
the models/runtime call; tests sweep them against `repro.kernels.ref`.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .coded_combine import coded_admm_update_kernel, coded_combine_kernel
from .flash_attention import flash_attention_kernel
from .rglru_scan import rglru_scan_kernel
from .ssd_scan import ssd_scan_kernel

__all__ = [
    "coded_combine",
    "coded_admm_update",
    "fit_block_n",
    "flash_attention",
    "ssd_scan",
    "rglru_scan",
]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(n: int, mult: int) -> int:
    return (n + mult - 1) // mult * mult


def fit_block_n(n: int, block_n: int = 4096, lane: int = 128) -> int:
    """Largest lane-legal tile <= block_n that avoids gross over-padding.

    The method-kernel step calls the fused ADMM update on flat (p*d,)
    vectors that can be much smaller than the default HBM tile; padding a
    640-float vector to 4096 would 6x the per-step work. Tiles stay
    multiples of the 128-lane vector width (pallas_guide 'Tiling
    Constraints').
    """
    return min(block_n, _pad_to(max(n, 1), lane))


# --------------------------------------------------------------------------


def _row_mask(mask, J, dtype) -> jax.Array:
    """Runtime alive mask, defaulting to all-alive. Always a traced (J,)
    array — mask VALUES never force a re-trace, only presence/absence
    (two cached traces at most per shape)."""
    if mask is None:
        return jnp.ones((J,), dtype)
    return jnp.asarray(mask, dtype)


@functools.partial(jax.jit, static_argnames=("block_n",))
def coded_combine(
    msgs: jax.Array,
    coeffs: jax.Array,
    mask: Optional[jax.Array] = None,
    *,
    block_n: int = 4096,
) -> jax.Array:
    """sum_j coeffs[j]*mask[j]*msgs[j] over flat message rows. msgs (J, n).

    ``mask`` (J,) marks alive rows (>0); dead rows are where-zeroed in
    the kernel so garbage (even NaN) in never-arrived messages cannot
    leak into the decode (DESIGN.md §11). None = all rows alive.
    """
    J, n = msgs.shape
    n_pad = _pad_to(n, block_n)
    if n_pad != n:
        msgs = jnp.pad(msgs, ((0, 0), (0, n_pad - n)))
    out = coded_combine_kernel(
        msgs, coeffs, _row_mask(mask, J, jnp.float32),
        block_n=block_n, interpret=_interpret(),
    )
    return out[:n]


@functools.partial(jax.jit, static_argnames=("block_n",))
def coded_admm_update(
    msgs: jax.Array,
    coeffs: jax.Array,
    x: jax.Array,
    y: jax.Array,
    z: jax.Array,
    tau: jax.Array,
    rho: jax.Array,
    mask: Optional[jax.Array] = None,
    *,
    block_n: int = 4096,
) -> jax.Array:
    """Fused decode + eq. (5a) x-update over flat parameter vectors.

    ``rho``/``tau`` are runtime scalars (python floats or traced arrays)
    and ``mask`` (J,) is a runtime alive-row mask: the method-kernel scan
    feeds per-iteration schedule values — decode coefficients, deadline
    truncation masks, step sizes — so none may force a re-trace."""
    J, n = msgs.shape
    n_pad = _pad_to(n, block_n)
    if n_pad != n:
        pad = ((0, 0), (0, n_pad - n))
        msgs = jnp.pad(msgs, pad)
        x = jnp.pad(x, (0, n_pad - n))
        y = jnp.pad(y, (0, n_pad - n))
        z = jnp.pad(z, (0, n_pad - n))
    out = coded_admm_update_kernel(
        msgs, coeffs, _row_mask(mask, J, jnp.float32), x, y, z, tau, rho,
        block_n=block_n, interpret=_interpret(),
    )
    return out[:n]


# --------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_offset", "block_q", "block_kv")
)
def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd) — model layout
    k: jax.Array,  # (B, Skv, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 256,
) -> jax.Array:
    """Flash attention in the model's (B, S, H, hd) layout, GQA-aware."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq) if Sq % block_q else block_q
    bkv = min(block_kv, Skv) if Skv % block_kv else block_kv
    # Fall back to legal tile sizes for short sequences.
    while Sq % bq:
        bq //= 2
    while Skv % bkv:
        bkv //= 2
    out = flash_attention_kernel(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=bq,
        block_kv=bkv,
        interpret=_interpret(),
    )
    return out.transpose(0, 2, 1, 3)


# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    *,
    chunk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan; pads S to a chunk multiple with dt=0 identity steps."""
    B, S, H, P = x.shape
    S_pad = _pad_to(S, chunk)
    if S_pad != S:
        pad = S_pad - S
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, h = ssd_scan_kernel(
        x,
        dt.astype(jnp.float32),
        A.astype(jnp.float32),
        Bm,
        Cm,
        chunk=chunk,
        interpret=_interpret(),
    )
    return y[:, :S], h


# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_s", "block_w"))
def rglru_scan(
    a: jax.Array,  # (B, S, W)
    b: jax.Array,  # (B, S, W)
    h0: Optional[jax.Array] = None,  # (B, W)
    *,
    block_s: int = 256,
    block_w: int = 512,
) -> Tuple[jax.Array, jax.Array]:
    """Linear recurrence h_t = a_t h_{t-1} + b_t (RG-LRU inner scan)."""
    B, S, W = a.shape
    if h0 is not None:
        # Fold initial state into step 0 (kernel starts from zero state).
        b = b.at[:, 0].add(a[:, 0] * h0.astype(b.dtype))
    bs = block_s
    while S % bs:
        bs //= 2
    h, hlast = rglru_scan_kernel(
        a, b, block_s=bs, block_w=block_w, interpret=_interpret()
    )
    return h, hlast
