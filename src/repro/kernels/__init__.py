"""Pallas TPU kernels for the compute hot-spots (validated interpret=True).

  coded_combine / coded_admm_update — fused MDS gradient decode (+ eq. 5a
      x-update): the csI-ADMM agent-side hot spot (memory-bound reduce).
  flash_attention — blocked online-softmax attention (causal / sliding
      window / GQA via index maps) for the transformer archs.
  ssd_scan — Mamba-2 chunked state-space-duality scan (mamba2-1.3b).
  rglru_scan — RG-LRU linear recurrence via in-kernel doubling scan
      (recurrentgemma-9b).

`ops` are the jitted public entry points; `ref` holds the pure-jnp oracles
the tests sweep against.
"""

from .ops import (
    coded_admm_update,
    coded_combine,
    flash_attention,
    rglru_scan,
    ssd_scan,
)

__all__ = [
    "coded_combine",
    "coded_admm_update",
    "flash_attention",
    "ssd_scan",
    "rglru_scan",
]
