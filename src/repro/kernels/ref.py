"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests).

Each function mirrors the semantics (including accumulation dtype: f32) of
its kernel twin but uses straightforward dense jnp ops, so correctness is
auditable at a glance.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "coded_combine_ref",
    "coded_admm_update_ref",
    "flash_attention_ref",
    "ssd_scan_ref",
    "rglru_scan_ref",
]


def coded_combine_ref(
    msgs: jax.Array,
    coeffs: jax.Array,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """out = sum_j coeffs[j] * mask[j]>0 * msgs[j] in the accumulation
    dtype (f32, or f64 under x64). msgs (J, n), coeffs/mask (J,).

    ``mask`` where-zeroes dead rows BEFORE the reduction, mirroring the
    kernel's NaN-safe guard (0 * NaN would be NaN, where is not).
    """
    ct = jnp.promote_types(msgs.dtype, jnp.float32)
    m = msgs.astype(ct)
    if mask is not None:
        m = jnp.where(mask[:, None] > 0, m, jnp.zeros((), ct))
    return jnp.tensordot(coeffs.astype(ct), m, axes=1)


def coded_admm_update_ref(
    msgs: jax.Array,  # (J, n) coded gradient messages
    coeffs: jax.Array,  # (J,) decode vector (already includes the 1/K of eq. 6)
    x: jax.Array,  # (n,)
    y: jax.Array,  # (n,)
    z: jax.Array,  # (n,)
    tau: jax.Array,  # scalar tau^k
    rho: float,
    mask: Optional[jax.Array] = None,  # (J,) alive rows (>0)
) -> jax.Array:
    """Fused decode + proximal x-update (eq. 5a):

    G = sum_j coeffs[j] mask[j] msgs[j];
    x+ = (tau x + rho z + y - G) / (rho + tau).
    """
    G = coded_combine_ref(msgs, coeffs, mask)
    ct = G.dtype
    t = tau.astype(ct)
    num = t * x.astype(ct) + rho * z.astype(ct) + y.astype(ct) - G
    return (num / (rho + t)).astype(x.dtype)


def flash_attention_ref(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, KV, Skv, hd)
    v: jax.Array,  # (B, KV, Skv, hd)
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    """Dense attention with GQA head mapping h -> h * KV // H."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    kv_idx = jnp.arange(H) * KV // H
    kx = k[:, kv_idx]  # (B, H, Skv, hd)
    vx = v[:, kv_idx]
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32)
    ) / jnp.sqrt(jnp.float32(hd))
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos[None] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None] > qpos[:, None] - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32))
    return o.astype(q.dtype)


def ssd_scan_ref(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) f32 post-softplus
    A: jax.Array,  # (H,) f32 negative
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    h0: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Sequential SSD recurrence (the mathematical definition):

    h_t = exp(dt_t A) h_{t-1} + (dt_t x_t) B_t^T ;  y_t = h_t C_t.
    Returns (y (B,S,H,P) f32, h_final (B,H,P,N) f32).
    """
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    h = (
        jnp.zeros((B_, H, P, N), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )

    def step(h, t):
        a = jnp.exp(dt[:, t, :, None, None] * A[None, :, None, None])
        xdt = x[:, t].astype(jnp.float32) * dt[:, t, :, None]
        h = a * h + jnp.einsum(
            "bhp,bn->bhpn", xdt, Bm[:, t].astype(jnp.float32)
        )
        y = jnp.einsum("bhpn,bn->bhp", h, Cm[:, t].astype(jnp.float32))
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3), h


def rglru_scan_ref(
    a: jax.Array,  # (B, S, W) f32 decay in (0, 1]
    b: jax.Array,  # (B, S, W) f32 input term
    h0: Optional[jax.Array] = None,  # (B, W)
) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + b_t. Returns (h_seq (B,S,W) f32, h_last)."""
    B_, S, W = a.shape
    h = jnp.zeros((B_, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        h = a[:, t].astype(jnp.float32) * h + b[:, t].astype(jnp.float32)
        return h, h

    h, hs = jax.lax.scan(step, h, jnp.arange(S))
    return hs.transpose(1, 0, 2), h
