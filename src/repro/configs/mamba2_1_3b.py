"""Mamba-2 1.3B — attention-free SSD (state-space duality)
[arXiv:2405.21060]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    vocab=50280,
    ssm_state=128,
    ssm_heads=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=128,
    vocab=512,
    ssm_state=16,
    ssm_heads=8,
    ssm_head_dim=32,
    ssm_expand=2,
    ssm_chunk=32,
    conv_width=4,
    tie_embeddings=True,
    dtype="float32",
)
