"""Assigned architecture configs + input shapes.

Each ``<arch>.py`` exposes ``CONFIG`` (the exact assigned hyper-parameters,
with source citation) and ``SMOKE`` (a reduced same-family variant: <=2-3
layers, d_model <= 512, <= 4 experts) for CPU smoke tests.
"""

from .registry import ARCHS, SHAPES, get_config, get_smoke_config, input_specs

__all__ = ["ARCHS", "SHAPES", "get_config", "get_smoke_config", "input_specs"]
