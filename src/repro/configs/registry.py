"""Architecture registry + input specs (ShapeDtypeStruct stand-ins).

``input_specs(arch, shape)`` builds the exact abstract inputs each step
function is lowered with in the multi-pod dry-run — weak-type-correct,
shardable, and never allocated.
"""

from __future__ import annotations

import importlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, get_model

from .shapes import InputShape
from .shapes import SHAPES as SHAPES  # re-exported via repro.configs

VIS_PREFIX = 256  # stub vision tokens prepended for VLM configs

_ARCH_MODULES = {
    "mixtral-8x22b": "mixtral_8x22b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "llama3-405b": "llama3_405b",
    "stablelm-1.6b": "stablelm_1_6b",
    "mamba2-1.3b": "mamba2_1_3b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "internlm2-20b": "internlm2_20b",
    "qwen3-0.6b": "qwen3_0_6b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-medium": "whisper_medium",
}

ARCHS = tuple(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(ARCHS)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """None if (arch, shape) runs; else a reason string for the skip."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return (
            "full quadratic attention at 524k context — skipped per "
            "assignment rules (no sliding-window/block-sparse variant in "
            "the cited config); see DESIGN.md §4"
        )
    return None


def _extra_embeds_spec(cfg: ModelConfig, B: int, dtype) -> Optional[jax.ShapeDtypeStruct]:
    if cfg.modality == "vision_stub":
        return jax.ShapeDtypeStruct((B, VIS_PREFIX, cfg.d_model), dtype)
    if cfg.modality == "audio_stub":
        return jax.ShapeDtypeStruct((B, cfg.encoder_positions, cfg.d_model), dtype)
    return None


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Abstract inputs for the step function selected by ``shape.kind``.

    train  -> {"tokens", "labels"[, "extra_embeds"]}
    prefill-> {"tokens"[, "extra_embeds"]}
    decode -> {"cache", "token"}  (cache from eval_shape of init_cache)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = cfg.jnp_dtype
    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        ee = _extra_embeds_spec(cfg, B, dt)
        if ee is not None:
            batch["extra_embeds"] = ee
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        ee = _extra_embeds_spec(cfg, B, dt)
        if ee is not None:
            batch["extra_embeds"] = ee
        return batch
    if shape.kind == "decode":
        model = get_model(cfg)
        cache = jax.eval_shape(lambda: model.init_cache(B, S))
        return {
            "cache": cache,
            "token": jax.ShapeDtypeStruct((B, 1), i32),
        }
    raise ValueError(shape.kind)


def make_concrete_batch(
    cfg: ModelConfig, shape: InputShape, seed: int = 0
) -> dict:
    """Concrete (host-RNG) batch matching input_specs — smoke tests/examples."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape)

    def realize(s):
        if np.issubdtype(s.dtype, np.integer):
            return jnp.asarray(
                rng.integers(0, max(cfg.vocab - 1, 2), size=s.shape, dtype=np.int32)
            )
        return jnp.asarray(rng.standard_normal(s.shape), dtype=s.dtype)

    return jax.tree.map(realize, specs)
