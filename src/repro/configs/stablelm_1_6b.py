"""StableLM 2 1.6B — dense, MHA (kv=32), partial rotary (25%), LayerNorm
[hf:stabilityai/stablelm-2-1_6b]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab=100352,
    rope_fraction=0.25,
    norm="layernorm",
    mlp_act="swiglu",
)

SMOKE = ModelConfig(
    name="stablelm-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab=512,
    rope_fraction=0.25,
    norm="layernorm",
    dtype="float32",
)
