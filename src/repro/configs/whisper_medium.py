"""Whisper medium — encoder-decoder, conv frontend stubbed
[arXiv:2212.04356]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,  # decoder layers
    encoder_layers=24,
    encoder_positions=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    norm="layernorm",
    mlp_act="gelu",
    modality="audio_stub",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    encoder_layers=2,
    encoder_positions=64,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab=512,
    norm="layernorm",
    mlp_act="gelu",
    modality="audio_stub",
    tie_embeddings=True,
    dtype="float32",
)
