"""RecurrentGemma 9B — RG-LRU + local attention, 1:2 pattern
[arXiv:2402.19427]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,  # MQA
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    lru_width=4096,
    attn_every=3,  # [rec, rec, attn] — the paper's 1:2 ratio
    sliding_window=2048,  # local attention window
    mlp_act="geglu",
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    n_layers=3,  # one full [rec, rec, attn] group
    d_model=128,
    n_heads=4,
    n_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab=512,
    lru_width=128,
    attn_every=3,
    sliding_window=64,
    mlp_act="geglu",
    dtype="float32",
)
