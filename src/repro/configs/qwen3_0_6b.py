"""Qwen3 0.6B — dense, qk-norm, GQA [hf:Qwen/Qwen3-0.6B family card]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    mlp_act="swiglu",
)

SMOKE = ModelConfig(
    name="qwen3-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
    qk_norm=True,
    tie_embeddings=True,
    dtype="float32",
)
