"""Mixtral 8x22B — MoE, 8 experts top-2, GQA, SWA [arXiv:2401.04088]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    n_experts=8,
    experts_per_token=2,
    sliding_window=4096,  # assignment lists SWA for this entry
    rope_theta=1e6,
    mlp_act="swiglu",
)

SMOKE = ModelConfig(
    name="mixtral-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
    n_experts=4,
    experts_per_token=2,
    capacity_factor=8.0,
    sliding_window=64,
    dtype="float32",
)
