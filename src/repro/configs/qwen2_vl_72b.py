"""Qwen2-VL 72B — VLM backbone, M-RoPE, dynamic resolution (vision stub)
[arXiv:2409.12191]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    mrope_sections=(16, 24, 24),  # sums to head_dim/2
    rope_theta=1e6,
    modality="vision_stub",
    mlp_act="swiglu",
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke",
    family="vlm",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
    mrope_sections=(4, 6, 6),
    modality="vision_stub",
    dtype="float32",
)
