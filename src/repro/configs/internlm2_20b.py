"""InternLM2 20B — dense, GQA [arXiv:2403.17297]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92544,
    rope_theta=1e6,
    mlp_act="swiglu",
)

SMOKE = ModelConfig(
    name="internlm2-smoke",
    family="dense",
    n_layers=2,
    d_model=192,
    n_heads=6,
    n_kv_heads=2,
    head_dim=32,
    d_ff=384,
    vocab=512,
    dtype="float32",
)
