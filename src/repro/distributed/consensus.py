"""csI-ADMM as a distributed-training feature on a TPU mesh.

Mapping (DESIGN.md §3):

  agents  -> the mesh's "agent" axis (the pod axis on multi-pod meshes, a
             data-axis split on single-pod meshes). Agent i's primal/dual
             (x_i, y_i) are pytrees with a leading A dim sharded over
             "agent" — each agent's copy lives only on its subgroup, so
             per-device bytes match ONE FSDP-sharded model, not A of them.
  z token -> consensus variable sharded over ("agent","data") — the paper's
             token traversal becomes an all-gather of z over the agent axis
             (one model's worth of ICI traffic per step, the exact analogue
             of "one token hop per iteration").
  ECNs    -> K equal subgroups of each agent's data axis. The input batch
             arrives CODED-ALLOCATED (dataloader repeats partition t on the
             S+1 ECNs whose encode rows touch it, paper Alg. 2 steps 2-9),
             so rows are laid out (A, K, S+1, P) along dim 0.

The encode/decode collapses into one weighted backward pass: gradients are
linear in per-example losses, so ECN j's encoded message sum_t B[j,t] g~_t
followed by the agent's decode sum_j a_j g_j is the gradient of the
row-weighted loss with w_row = a_j * B[j, t(row)] / (K * P). The decode
vector a(alive) is recomputed in-jit from the straggler mask via pinv —
dead ECNs get coefficient exactly 0 (min-norm solution), so their rows'
compute is masked out just like a timed-out response.

Redundancy is honest: the assigned global batch B carries (S+1)-replicated
rows, so the effective mini-batch is B/(S+1) — eq. (22)'s M_bar = M/(S+1)
trade-off, visible in the framework rather than assumed.

Modes:
  incremental (paper-faithful): only agent (k mod A) applies its update;
      all agents compute (SPMD lockstep) but non-active deltas are masked.
  parallel (beyond-paper): every agent updates every step (PW-ADMM-style);
      z absorbs the average delta. Same per-step cost, A x the progress —
      recorded separately in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.coding import GradientCode, make_code

from .sharding import AxisLayout, tree_specs

__all__ = ["ConsensusConfig", "ConsensusRuntime"]


@dataclasses.dataclass(frozen=True)
class ConsensusConfig:
    """Hyper-parameters of the distributed csI-ADMM runtime."""

    n_agents: int = 2
    K: int = 4  # ECN groups per agent
    S: int = 1  # tolerated stragglers per agent
    scheme: str = "cyclic"  # "uncoded" | "fractional" | "cyclic"
    rho: float = 1.0
    c_tau: float = 0.1  # tau^k = c_tau sqrt(k)
    c_gamma: float = 1.0  # gamma^k = c_gamma / sqrt(k)
    mode: str = "incremental"  # "incremental" (paper) | "parallel" (beyond)
    seed: int = 0

    def code(self) -> GradientCode:
        return make_code(self.scheme, self.K, self.S, seed=self.seed)


def make_consensus_mesh(
    n_agents: int, multi_pod: bool = False
) -> Mesh:
    """The production mesh refined with an explicit agent axis.

    multi-pod: the pod axis IS the agent axis ((2,16,16) ->
    ("agent","data","model"), 512 chips). single-pod: the 16-wide data axis
    splits into (agents, data) ((A, 16//A, 16), 256 chips).
    """
    if multi_pod:
        if n_agents != 2:
            raise ValueError("multi-pod mesh has 2 pods = 2 agents")
        return jax.make_mesh((2, 16, 16), ("agent", "data", "model"))
    if 16 % n_agents:
        raise ValueError(f"n_agents={n_agents} must divide 16")
    return jax.make_mesh(
        (n_agents, 16 // n_agents, 16), ("agent", "data", "model")
    )


class ConsensusRuntime:
    """Builds sharded init / train-step callables for one (model, mesh)."""

    def __init__(self, model, cfg: ConsensusConfig, mesh: Mesh):
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        self.layout = AxisLayout(mesh, data=("data",), model="model", agent="agent")
        code = cfg.code()
        # Static encode-structure constants: ECN j's u-th stored partition
        # id and its encode coefficient B[j, supp(j)[u]].
        sup = np.stack([code.support(j) for j in range(cfg.K)])  # (K, S+1)
        if sup.shape[1] != cfg.S + 1:
            raise ValueError(
                f"{cfg.scheme} code stores {sup.shape[1]} partitions/ECN, "
                f"expected S+1={cfg.S + 1}"
            )
        self.B_enc = jnp.asarray(code.B, jnp.float32)  # (K, K)
        self.B_sel = jnp.asarray(
            np.take_along_axis(code.B, sup, axis=1), jnp.float32
        )  # (K, S+1)
        self.support = jnp.asarray(sup, jnp.int32)

    # -- state ---------------------------------------------------------------

    def state_shape(self, params_shape: Any) -> Any:
        """Abstract consensus state from abstract params (dry-run safe)."""
        A = self.cfg.n_agents

        def rep(leaf):
            return jax.ShapeDtypeStruct((A, *leaf.shape), leaf.dtype)

        return {
            "x": jax.tree.map(rep, params_shape),
            "y": jax.tree.map(rep, params_shape),
            "z": params_shape,
            "k": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def state_specs(self, params_shape: Any) -> Any:
        ly = self.layout
        zly = AxisLayout(self.mesh, data=("agent", "data"), model="model")
        return {
            "x": tree_specs(
                jax.tree.map(
                    lambda l: jax.ShapeDtypeStruct((self.cfg.n_agents, *l.shape), l.dtype),
                    params_shape,
                ),
                ly,
                leading=("agent",),
            ),
            "y": tree_specs(
                jax.tree.map(
                    lambda l: jax.ShapeDtypeStruct((self.cfg.n_agents, *l.shape), l.dtype),
                    params_shape,
                ),
                ly,
                leading=("agent",),
            ),
            # z FSDP-shards over BOTH agent and data axes: the per-step
            # all-gather of z over "agent" is the token traversal.
            "z": tree_specs(params_shape, zly),
            "k": P(),
        }

    def init_state(self, rng: jax.Array) -> Any:
        """Concrete init (small models / examples; z = init params, x=y=0)."""
        params = self.model.init(rng)
        A = self.cfg.n_agents
        x = jax.tree.map(lambda p: jnp.broadcast_to(p, (A, *p.shape)).copy(), params)
        y = jax.tree.map(lambda p: jnp.zeros((A, *p.shape), p.dtype), params)
        return {"x": x, "y": y, "z": params, "k": jnp.zeros((), jnp.int32)}

    # -- step ----------------------------------------------------------------

    def row_weights(self, alive: jax.Array, rows_per_agent: int) -> jax.Array:
        """(A, rows_per_agent) loss weights from the (A, K) alive mask.

        Decode vector per agent: min-norm a with a^T (B masked to alive rows)
        = 1^T; dead ECNs receive coefficient exactly 0 (their e_j lies in
        null(B_alive^T), and the pinv solution is orthogonal to it).
        """
        cfg = self.cfg
        K, S1 = cfg.K, cfg.S + 1
        P_rows = rows_per_agent // (K * S1)
        # Solve in the widest enabled precision (f64 under x64, else f32) —
        # decode exactness is a property of the certified code; the solve
        # should not be the noise floor.
        ftype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        Bm = self.B_enc.astype(ftype)[None] * alive[..., None].astype(ftype)
        ones = jnp.ones((cfg.K,), ftype)
        a = jax.vmap(lambda M: jnp.linalg.pinv(M.T, rtol=1e-6) @ ones)(Bm)
        a = a.astype(jnp.float32)
        # w[a, j, u, :] = a_j * B[j, sup(j)[u]] / (K * P)
        w = (
            a[:, :, None] * self.B_sel[None] / (K * P_rows)
        )  # (A, K, S+1)
        return jnp.broadcast_to(
            w[..., None], (*w.shape, P_rows)
        ).reshape(alive.shape[0], rows_per_agent)

    def train_step(
        self, state: Any, batch: Any, alive: jax.Array
    ) -> Tuple[Any, dict]:
        """One csI-ADMM iteration (eqs. 5a, 5b, 4c) over the mesh.

        batch leaves are (B_global, ...) with B_global = A*K*(S+1)*P rows in
        coded allocation order; alive is the (A, K) ECN response mask.
        """
        cfg = self.cfg
        A = cfg.n_agents
        k = state["k"] + 1
        kf = k.astype(jnp.float32)
        tau = cfg.c_tau * jnp.sqrt(kf)
        gamma = cfg.c_gamma / jnp.sqrt(kf)
        rho = cfg.rho

        tokens = batch["tokens"]
        Bg = tokens.shape[0]
        rows = Bg // A
        w = self.row_weights(alive, rows)  # (A, rows)

        def reshape_agent(leaf):
            return leaf.reshape(A, rows, *leaf.shape[1:])

        abatch = jax.tree.map(reshape_agent, batch)

        def agent_loss(x_a, batch_a, w_a):
            b = dict(batch_a, loss_weights=w_a)
            (loss, metrics), grads = jax.value_and_grad(
                self.model.loss, has_aux=True
            )(x_a, b)
            return grads, loss, metrics["nll"]

        grads, losses, nlls = jax.vmap(agent_loss)(
            state["x"], abatch, w
        )  # grads: (A, ...) pytree

        # eq. (5a): x+ = (tau x + rho z + y - G) / (rho + tau), all agents.
        def x_upd(x, y, z, g):
            num = (
                tau * x.astype(jnp.float32)
                + rho * z[None].astype(jnp.float32)
                + y.astype(jnp.float32)
                - g.astype(jnp.float32)
            )
            return (num / (rho + tau)).astype(x.dtype)

        x_new = jax.tree.map(x_upd, state["x"], state["y"], state["z"], grads)

        # eq. (5b): y+ = y + rho gamma (z - x+).
        def y_upd(y, z, xn):
            return (
                y.astype(jnp.float32)
                + rho * gamma * (z[None].astype(jnp.float32) - xn.astype(jnp.float32))
            ).astype(y.dtype)

        y_new = jax.tree.map(y_upd, state["y"], state["z"], x_new)

        if cfg.mode == "incremental":
            # Paper-faithful: only agent i_k = (k-1) mod A commits.
            active = (k - 1) % A
            m = (jnp.arange(A) == active).astype(jnp.float32)

            def sel(new, old):
                mm = m.reshape((A,) + (1,) * (new.ndim - 1)).astype(jnp.float32)
                return (
                    mm * new.astype(jnp.float32)
                    + (1 - mm) * old.astype(jnp.float32)
                ).astype(new.dtype)

            x_new = jax.tree.map(sel, x_new, state["x"])
            y_new = jax.tree.map(sel, y_new, state["y"])
            scale = 1.0 / A  # eq. (4c) 1/N with one active delta
            mask = m
        else:  # parallel (beyond-paper): every agent commits, z averages
            scale = 1.0 / A
            mask = jnp.ones((A,), jnp.float32)

        # eq. (4c): z+ = z + sum_a mask_a [(x_a+ - x_a) - (y_a+ - y_a)/rho]/A.
        def z_upd(z, xn, xo, yn, yo):
            mm = mask.reshape((A,) + (1,) * (xn.ndim - 1))
            delta = (
                (xn.astype(jnp.float32) - xo.astype(jnp.float32))
                - (yn.astype(jnp.float32) - yo.astype(jnp.float32)) / rho
            )
            return (
                z.astype(jnp.float32) + scale * jnp.sum(mm * delta, axis=0)
            ).astype(z.dtype)

        z_new = jax.tree.map(
            z_upd, state["z"], x_new, state["x"], y_new, state["y"]
        )

        # consensus residual ||z - x_a|| (flattened, f32)
        def sq(xn, z):
            d = xn.astype(jnp.float32) - z[None].astype(jnp.float32)
            return jnp.sum(d * d, axis=tuple(range(1, d.ndim)))

        res = jnp.sqrt(
            sum(jax.tree.leaves(jax.tree.map(sq, x_new, z_new)))
        )  # (A,)

        new_state = {"x": x_new, "y": y_new, "z": z_new, "k": k}
        metrics = {
            "loss": losses.mean(),
            "nll": nlls.mean(),
            "consensus_residual": res.mean(),
            "tau": tau,
            "gamma": gamma,
        }
        return new_state, metrics

    # -- jit plumbing ----------------------------------------------------------

    def lower_train_step(self, batch_shape: Any, params_shape: Any):
        """jit-lower the step on the mesh with explicit shardings (dry-run)."""
        state_shape = self.state_shape(params_shape)
        specs = self.state_specs(params_shape)
        from .sharding import batch_specs

        bspecs = batch_specs(batch_shape, self.layout)
        alive_shape = jax.ShapeDtypeStruct(
            (self.cfg.n_agents, self.cfg.K), jnp.bool_
        )
        with self.mesh:
            step = jax.jit(
                self.train_step,
                in_shardings=(
                    jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs),
                    jax.tree.map(lambda s: NamedSharding(self.mesh, s), bspecs),
                    NamedSharding(self.mesh, P()),
                ),
                out_shardings=(
                    jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs),
                    None,
                ),
            )
            return step.lower(state_shape, batch_shape, alive_shape)
