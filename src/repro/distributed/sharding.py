"""Sharding inference: FSDP x TP specs for arbitrary model pytrees.

One greedy rule drives every architecture (the assigned configs have wildly
different divisibility patterns — vocab 50280 doesn't divide 16, head counts
range 1..128 — so hand-written per-arch rules would be 10x the code and
still miss the reduced smoke variants):

  * "model" (TP) claims the RIGHTMOST dim divisible by its mesh size
    (weights are (.., D_in, D_out): sharding D_out gives column-parallel
    matmuls feeding row-parallel next layers — XLA SPMD inserts the psum);
  * the data axes (FSDP) claim the LEFTMOST remaining divisible dim,
    skipping dim 0 of stacked-layer arrays (ndim >= 3) so the lax.scan over
    layers never crosses a partition boundary;
  * dims that divide nothing stay replicated (e.g. mamba2's vocab 50280).

`auto_spec` is deliberately shape-only: it runs on ShapeDtypeStructs in the
dry-run without touching device state.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

__all__ = [
    "auto_spec",
    "tree_specs",
    "batch_specs",
    "cache_specs",
    "AxisLayout",
]


class AxisLayout:
    """Which mesh axes play which role for a given runtime.

    data axes may be a tuple (e.g. ("pod", "data") for fully-flat DP, or
    ("data",) with "pod" reserved as the consensus agent axis).
    """

    def __init__(
        self,
        mesh: Mesh,
        data: Sequence[str] = ("data",),
        model: str = "model",
        agent: Optional[str] = None,
    ):
        self.mesh = mesh
        self.data = tuple(data)
        self.model = model
        self.agent = agent
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.data_size = int(np.prod([sizes[a] for a in self.data]))
        self.model_size = sizes[model]
        self.agent_size = sizes[agent] if agent else 1

    def dp_spec(self) -> P:
        """Batch-dim spec over all data axes (agent axis first if present)."""
        axes = ((self.agent,) if self.agent else ()) + self.data
        return P(axes)


def auto_spec(
    shape: Tuple[int, ...],
    layout: AxisLayout,
    *,
    skip_layer_dim: bool = True,
    leading: Tuple[Optional[str], ...] = (),
) -> P:
    """Greedy FSDP x TP PartitionSpec for one array shape.

    ``leading`` pins specs for leading dims (e.g. ("agent",) for consensus
    x/y pytrees); the rule applies to the remaining dims.
    """
    n = len(shape)
    spec: list = [None] * n
    for i, ax in enumerate(leading):
        spec[i] = ax
    lo = len(leading)
    if n - lo == 0:
        return P(*spec)
    assigned = set()
    # TP: rightmost divisible dim.
    if layout.model_size > 1:
        for i in range(n - 1, lo - 1, -1):
            if shape[i] % layout.model_size == 0 and shape[i] >= layout.model_size:
                spec[i] = layout.model
                assigned.add(i)
                break
    # FSDP: leftmost remaining divisible dim (skip stacked-layer dim 0).
    first = lo + (1 if (skip_layer_dim and n - lo >= 3) else 0)
    if layout.data_size > 1:
        for i in range(first, n):
            if i in assigned:
                continue
            if shape[i] % layout.data_size == 0 and shape[i] >= layout.data_size:
                spec[i] = layout.data if len(layout.data) > 1 else layout.data[0]
                break
    return P(*spec)


def tree_specs(
    tree: Any,
    layout: AxisLayout,
    *,
    leading: Tuple[Optional[str], ...] = (),
) -> Any:
    """PartitionSpecs for every leaf of an (abstract or concrete) pytree."""
    return jax.tree.map(
        lambda leaf: auto_spec(np.shape(leaf), layout, leading=leading), tree
    )


def batch_specs(batch: Any, layout: AxisLayout) -> Any:
    """Batch dict: dim 0 over all data axes, rest replicated."""
    dp = layout.dp_spec()

    def spec(leaf):
        shape = np.shape(leaf)
        total = layout.data_size * layout.agent_size
        if shape and shape[0] % total == 0 and shape[0] >= total:
            return P(dp[0], *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    return jax.tree.map(spec, batch)


def cache_specs(cache: Any, layout: AxisLayout) -> Any:
    """KV/state caches: batch dim (dim 1 of (L, B, ...) leaves) over data,
    TP on the rightmost divisible dim; scalars replicated."""

    def spec(leaf):
        shape = np.shape(leaf)
        if len(shape) <= 1:
            return P(*([None] * len(shape)))
        return auto_spec(shape, layout)

    return jax.tree.map(spec, cache)
