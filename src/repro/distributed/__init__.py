"""Distributed runtime: sharding rules, plain FSDP x TP steps, and the
paper's csI-ADMM consensus runtime as a first-class mesh feature."""

from .consensus import ConsensusConfig, ConsensusRuntime, make_consensus_mesh
from .plain import PlainRuntime
from .sharding import AxisLayout, auto_spec, batch_specs, cache_specs, tree_specs

__all__ = [
    "ConsensusConfig",
    "ConsensusRuntime",
    "make_consensus_mesh",
    "PlainRuntime",
    "AxisLayout",
    "auto_spec",
    "batch_specs",
    "cache_specs",
    "tree_specs",
]
