"""Plain FSDP x TP training / serving steps (the non-consensus baseline).

These are what the 40 (arch x shape) dry-run baselines lower: a standard
Adam training step for `train_*` shapes, prefill for `prefill_*`, and one
cached decode step for `decode_*` shapes. The consensus runtime
(`repro.distributed.consensus`) is the paper's technique layered on the
same sharding rules.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim import adam_init, adam_update, clip_by_global_norm

from .sharding import AxisLayout, batch_specs, cache_specs, tree_specs

__all__ = ["PlainRuntime"]


class PlainRuntime:
    """Sharded train/prefill/decode steps for one (model, mesh)."""

    def __init__(self, model, mesh: Mesh, lr: float = 3e-4):
        self.model = model
        self.mesh = mesh
        data = tuple(a for a in mesh.axis_names if a != "model")
        self.layout = AxisLayout(mesh, data=data, model="model")
        self.lr = lr

    # -- abstract state -------------------------------------------------------

    def params_shape(self) -> Any:
        return jax.eval_shape(lambda: self.model.init(jax.random.key(0)))

    def train_state_shape(self) -> Any:
        p = self.params_shape()
        return {"params": p, "opt": jax.eval_shape(adam_init, p)}

    def state_specs(self, state_shape: Any) -> Any:
        # Adam moments inherit their parameter's spec (same shapes).
        return tree_specs(state_shape, self.layout)

    # -- steps ------------------------------------------------------------------

    def train_step(self, state: Any, batch: Any) -> Tuple[Any, dict]:
        (loss, metrics), grads = jax.value_and_grad(
            self.model.loss, has_aux=True
        )(state["params"], batch)
        grads, gn = clip_by_global_norm(grads, 1.0)
        params, opt = adam_update(state["params"], grads, state["opt"], self.lr)
        return {"params": params, "opt": opt}, {
            "loss": loss,
            "nll": metrics["nll"],
            "grad_norm": gn,
        }

    def prefill_step(self, params: Any, batch: Any) -> Tuple[jax.Array, Any]:
        kwargs = {}
        if "extra_embeds" in batch:
            kwargs["extra_embeds"] = batch["extra_embeds"]
        return self.model.prefill(params, batch["tokens"], **kwargs)

    def serve_step(self, params: Any, cache: Any, token: jax.Array):
        return self.model.decode(params, cache, token)

    # -- lowering ------------------------------------------------------------

    def _ns(self, spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s),
            spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    def lower_train(self, batch_shape: Any):
        state_shape = self.train_state_shape()
        sspec = self.state_specs(state_shape)
        bspec = batch_specs(batch_shape, self.layout)
        with self.mesh:
            return jax.jit(
                self.train_step,
                in_shardings=(self._ns(sspec), self._ns(bspec)),
                out_shardings=(self._ns(sspec), None),
            ).lower(state_shape, batch_shape)

    def lower_prefill(self, batch_shape: Any):
        pshape = self.params_shape()
        pspec = tree_specs(pshape, self.layout)
        bspec = batch_specs(batch_shape, self.layout)
        with self.mesh:
            return jax.jit(
                self.prefill_step,
                in_shardings=(self._ns(pspec), self._ns(bspec)),
            ).lower(pshape, batch_shape)

    def lower_decode(self, cache_shape: Any, token_shape: Any):
        pshape = self.params_shape()
        pspec = tree_specs(pshape, self.layout)
        cspec = cache_specs(cache_shape, self.layout)
        tspec = batch_specs({"token": token_shape}, self.layout)["token"]
        with self.mesh:
            return jax.jit(
                self.serve_step,
                in_shardings=(
                    self._ns(pspec),
                    self._ns(cspec),
                    NamedSharding(self.mesh, tspec),
                ),
                out_shardings=(None, self._ns(cspec)),
            ).lower(pshape, cache_shape, token_shape)
