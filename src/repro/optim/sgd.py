"""SGD / Adam over pytrees (baselines + reference LM training loop)."""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["sgd_update", "adam_init", "adam_update", "clip_by_global_norm"]


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def sgd_update(params: Any, grads: Any, lr) -> Any:
    return jax.tree.map(
        lambda p, g: (p - lr * g.astype(jnp.float32)).astype(p.dtype),
        params,
        grads,
    )


def adam_init(params: Any) -> dict:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "t": jnp.zeros((), jnp.int32),
    }


def adam_update(
    params: Any,
    grads: Any,
    state: dict,
    lr,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Tuple[Any, dict]:
    t = state["t"] + 1
    tf = t.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_ = b1 * m + (1 - b1) * g32
        v_ = b2 * v + (1 - b2) * g32 * g32
        mhat = m_ / (1 - b1**tf)
        vhat = v_ / (1 - b2**tf)
        step = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m_, v_

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "t": t}
