"""tau^k / gamma^k schedules (Theorem 2) + generic step-size schedules."""

from __future__ import annotations

from typing import Callable, Tuple

import jax.numpy as jnp

__all__ = ["rsqrt_growth", "rsqrt_decay", "constant", "admm_schedule"]


def rsqrt_growth(c: float) -> Callable:
    """tau^k = c * sqrt(k) (k is 1-based)."""

    def f(k):
        return c * jnp.sqrt(jnp.asarray(k, jnp.float32))

    return f


def rsqrt_decay(c: float) -> Callable:
    """gamma^k = c / sqrt(k) (k is 1-based)."""

    def f(k):
        return c / jnp.sqrt(jnp.asarray(k, jnp.float32))

    return f


def constant(c: float) -> Callable:
    def f(k):
        return jnp.full((), c, jnp.float32)

    return f


def admm_schedule(
    c_tau: float, c_gamma: float
) -> Tuple[Callable, Callable]:
    """The (tau^k, gamma^k) pair sI-ADMM converges under (Theorem 2)."""
    return rsqrt_growth(c_tau), rsqrt_decay(c_gamma)
