"""Optimizers and schedules.

The paper's x-update (eq. 5a) *is* the optimizer for ADMM agents — it lives
in `repro.core.admm` / `repro.distributed.consensus`. This package provides:

- the tau^k / gamma^k schedules of Theorem 2,
- plain SGD / Adam used by the gradient-descent baselines (DGD) and by the
  non-consensus reference training loop in examples,
- gradient clipping / weight-decay utilities shared by the launcher.
"""

from .schedules import admm_schedule, constant, rsqrt_decay, rsqrt_growth
from .sgd import adam_init, adam_update, sgd_update, clip_by_global_norm

__all__ = [
    "admm_schedule",
    "constant",
    "rsqrt_decay",
    "rsqrt_growth",
    "adam_init",
    "adam_update",
    "sgd_update",
    "clip_by_global_norm",
]
