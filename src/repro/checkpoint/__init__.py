"""Host checkpointing of arbitrary pytrees as flat .npz archives."""

from .npz import load_pytree, save_pytree, latest_step, save_step, restore_step

__all__ = [
    "save_pytree",
    "load_pytree",
    "save_step",
    "restore_step",
    "latest_step",
]
