"""Flat-key .npz pytree checkpointing (atomic writes, step directories).

Keys flatten the pytree path with '/'; bfloat16 leaves round-trip via a
uint16 view (npz has no bf16 dtype) recorded in a sidecar '__bf16__' list.
"""

from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_pytree", "load_pytree", "save_step", "restore_step", "latest_step"]

_SEP = "/"


def _flatten(tree: Any) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(path: str, tree: Any) -> None:
    flat = _flatten(tree)
    bf16 = [k for k, v in flat.items() if v.dtype == jnp.bfloat16]
    arrays = {
        k: (v.view(np.uint16) if k in bf16 else v) for k, v in flat.items()
    }
    arrays["__bf16__"] = np.array(bf16, dtype=np.str_)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # atomic: write to a temp file in the same dir, then rename
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_pytree(path: str, like: Any) -> Any:
    """Load into the structure of ``like`` (shapes/dtypes validated)."""
    with np.load(path, allow_pickle=False) as z:
        bf16 = set(z["__bf16__"].tolist()) if "__bf16__" in z else set()
        flat = {k: z[k] for k in z.files if k != "__bf16__"}
    ref = _flatten(like)
    if set(flat) != set(ref):
        missing = set(ref) - set(flat)
        extra = set(flat) - set(ref)
        raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")
    leaves_ref, treedef = jax.tree_util.tree_flatten(like)
    paths = [
        _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    out = []
    for key, ref_leaf in zip(paths, leaves_ref):
        arr = flat[key]
        if key in bf16:
            arr = arr.view(jnp.bfloat16)
        if arr.shape != np.shape(ref_leaf):
            raise ValueError(
                f"{key}: shape {arr.shape} != expected {np.shape(ref_leaf)}"
            )
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def save_step(ckpt_dir: str, step: int, tree: Any) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    save_pytree(path, tree)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)\.npz", f))
    ]
    return max(steps) if steps else None


def restore_step(
    ckpt_dir: str, like: Any, step: Optional[int] = None
) -> Tuple[Any, int]:
    """Load a step checkpoint into the structure of ``like``.

    Returns ``(tree, step)``: the restored pytree plus the step number it
    came from (the latest checkpoint in ``ckpt_dir`` when ``step`` is
    None) — callers resume their loop counters from the second element.
    """
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    return load_pytree(os.path.join(ckpt_dir, f"step_{step:08d}.npz"), like), step
