"""Static trace-contract analysis (DESIGN.md §14).

Two enforcement layers over the invariants every performance claim in
this repo rests on:

- `repro.analysis.astcheck` — an AST linter for the contracts that are
  visible in source: the host/device split of `MethodKernel` (DESIGN.md
  §2, §8), trace-safety of step bodies, spec-dataclass immutability,
  and statics-key completeness.
- `repro.analysis.traceaudit` — a jaxpr audit that lowers every
  registered kernel over a representative static-signature grid and
  asserts structural properties of the traced program (fused Pallas
  path present, zero callbacks, no silent f64->f32 demotion, pinned
  trace counts per static group) against the committed
  ``benchmarks/trace_audit.json``.

Both run via ``make trace-lint`` (`tools/trace_lint.py`) and gate CI.
"""

from .astcheck import Finding, RULES, lint_paths

__all__ = ["Finding", "RULES", "lint_paths"]
