"""AST invariant linter: the trace contracts, enforced at analysis time.

Every execution tier in this repo is derived from ONE step function per
algorithm (DESIGN.md §8), and every sweep headline depends on contracts
that used to be enforced only by hand-audit after a regression (the PR 7
fused-reduction identity drift, the PR 8 D-ADMM async discontinuity).
This module turns those contracts into lint rules over ``src/``
(DESIGN.md §14):

- ``host-rng-in-device-code``: ``prepare`` samples everything random
  host-side; device-side kernel methods (setup/init/step/final and the
  hooks they call) and the Pallas modules under ``repro/kernels`` must
  never touch ``np.random``/``random`` — host RNG inside a scan body is
  either a trace-time constant (silently frozen noise) or a crash.
- ``device-array-in-host-prepare``: the host side of the split
  (``prepare``/``static_signature``/``config`` and their helpers) must
  stay pure numpy. A ``jnp`` array materialized there devices-commits
  host data before the driver stacks/shards it (DESIGN.md §2).
- ``traced-python-control-flow``: no Python ``if``/``while``/
  ``assert``/``bool()``/``float()``/``int()``/``.item()`` on traced
  values inside device-side methods. Branching is only legal on
  ``statics`` (the jit cache key) — anything else either fails to trace
  or forces a retrace per value, breaking the one-trace-per-group
  dispatch contract (DESIGN.md §7).
- ``callback-in-scan-body``: no ``jax.debug``/``io_callback``/
  ``pure_callback`` in device-side methods — a callback inside the
  vmapped scan serializes every iteration through the host and breaks
  the sharded tier (pallas_call + callbacks have no SPMD story,
  DESIGN.md §9).
- ``spec-dataclass-not-frozen``: spec dataclasses (``*Config``,
  ``*Run``, ``*Spec``, `Case`, `Reduction`, `TimingModel`, ...) are jit
  cache keys and grid dedupe keys; they must be ``frozen=True`` with no
  mutable defaults.
- ``statics-key-not-in-signature``: every ``statics[...]`` key a
  device-side method reads must be produced by some kernel's host-side
  statics construction — a key read under Python control flow in
  ``step`` but absent from the statics dict is a latent KeyError and a
  signature-completeness hole (the statics dict IS the jit cache key).
The linter is pure stdlib ``ast`` — no jax import — so it runs as a
cold CI step. Class relationships are resolved by name across all
linted files (MethodKernel subclasses found transitively), and
host/device method sets are computed per class by a ``self.method()``
call-graph fixpoint seeded with the protocol's known host entry points
(``prepare``/``config``/``static_signature``/``max_statics_bound``) and
device entry points (``setup``/``init``/``step``/``final``). A method
reachable from both sides is skipped as ambiguous rather than
mis-flagged. Fixture corpus: ``tests/fixtures/lint``.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["Finding", "RULES", "lint_paths"]


RULES: Dict[str, str] = {
    "host-rng-in-device-code": (
        "host RNG (np.random / random) inside a device-side kernel method"
    ),
    "device-array-in-host-prepare": (
        "jax/jnp usage inside a host-side (prepare-path) kernel method"
    ),
    "traced-python-control-flow": (
        "Python control flow / cast on a traced value in a device-side "
        "method"
    ),
    "callback-in-scan-body": (
        "jax.debug / io_callback / pure_callback inside a device-side "
        "method"
    ),
    "spec-dataclass-not-frozen": (
        "spec dataclass not frozen=True, or carries a mutable default"
    ),
    "statics-key-not-in-signature": (
        "statics key read device-side but never produced by any "
        "host-side statics construction"
    ),
}

# The MethodKernel protocol's fixed entry points (DESIGN.md §8).
_DEVICE_SEED = ("setup", "init", "step", "final")
_HOST_SEED = ("config", "static_signature", "prepare", "max_statics_bound")

# Spec dataclasses are jit/grid keys; result containers are not.
_SPEC_SUFFIXES = ("Config", "Run", "Spec")
_SPEC_NAMES = {"Case", "Reduction", "TimingModel", "GradientCode",
               "CodeFamily"}
_SPEC_ALLOWLIST = {"SweepResult", "Prepared"}

# Attribute reads that are static under tracing even on traced values.
_STATIC_ATTRS = {"shape", "dtype", "ndim", "size"}
# Builtins whose result is Python-level even for traced arguments.
_STATIC_CALLS = {"len", "isinstance", "hasattr", "getattr", "range",
                 "min", "max", "sorted", "enumerate", "zip"}
_CAST_CALLS = {"bool", "float", "int", "complex"}
_CALLBACK_NAMES = {"io_callback", "pure_callback", "debug_callback",
                   "callback"}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# --------------------------------------------------------------------------
# Small AST helpers
# --------------------------------------------------------------------------


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for nested Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _base_name(node: ast.AST) -> Optional[str]:
    """Last component of a class base expression (Name or Attribute)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _is_dataclass_decorator(dec: ast.AST) -> Optional[ast.Call]:
    """The decorator Call if ``dec`` is (a call of) dataclass, else a
    sentinel empty Call for the bare form, else None."""
    target = dec.func if isinstance(dec, ast.Call) else dec
    name = _dotted(target)
    if name in ("dataclass", "dataclasses.dataclass"):
        return dec if isinstance(dec, ast.Call) else ast.Call(
            func=target, args=[], keywords=[]
        )
    return None


def _is_mutable_default(value: ast.AST) -> bool:
    """Would this default expression alias shared mutable state?"""
    if isinstance(value, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                          ast.DictComp, ast.SetComp)):
        return True
    if isinstance(value, ast.Call):
        name = _dotted(value.func) or ""
        if name in ("list", "dict", "set", "bytearray"):
            return True
        if name.startswith(("np.", "numpy.", "jnp.", "jax.")):
            return True
    return False


# --------------------------------------------------------------------------
# Project index: classes, kernel resolution, method classification
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _ClassInfo:
    name: str
    path: pathlib.Path
    node: ast.ClassDef
    bases: Tuple[str, ...]

    def methods(self) -> Dict[str, ast.FunctionDef]:
        return {
            item.name: item
            for item in self.node.body
            if isinstance(item, ast.FunctionDef)
        }


class _Index:
    """Name-resolved view of every linted module (stdlib-only)."""

    def __init__(self, files: Dict[pathlib.Path, ast.Module]):
        self.files = files
        self.classes: Dict[str, List[_ClassInfo]] = {}
        for path, tree in files.items():
            for node in ast.walk(tree):
                if isinstance(node, ast.ClassDef):
                    bases = tuple(
                        b for b in map(_base_name, node.bases) if b
                    )
                    self.classes.setdefault(node.name, []).append(
                        _ClassInfo(node.name, path, node, bases)
                    )

    def kernel_classes(self) -> List[_ClassInfo]:
        """Transitive subclasses of MethodKernel, resolved by base name."""
        kernel_names: Set[str] = {"MethodKernel"}
        changed = True
        while changed:
            changed = False
            for name, infos in self.classes.items():
                if name in kernel_names:
                    continue
                if any(
                    b in kernel_names for info in infos for b in info.bases
                ):
                    kernel_names.add(name)
                    changed = True
        out = []
        for name in kernel_names:
            out.extend(self.classes.get(name, []))
        return sorted(out, key=lambda c: (str(c.path), c.node.lineno))

    def flattened_methods(
        self, cls: _ClassInfo
    ) -> Dict[str, ast.FunctionDef]:
        """Own methods + nearest inherited ones (name-resolved MRO-ish)."""
        resolved: Dict[str, ast.FunctionDef] = {}
        seen: Set[str] = set()
        queue: List[_ClassInfo] = [cls]
        while queue:
            info = queue.pop(0)
            if info.name in seen:
                continue
            seen.add(info.name)
            for mname, fn in info.methods().items():
                resolved.setdefault(mname, fn)
            for base in info.bases:
                queue.extend(self.classes.get(base, []))
        return resolved


def _self_calls(fn: ast.FunctionDef) -> Set[str]:
    """Names of methods invoked as ``self.X(...)`` / ``cls.X(...)``."""
    calls: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(
            node.func, ast.Attribute
        ):
            root = node.func.value
            if isinstance(root, ast.Name) and root.id in ("self", "cls"):
                calls.add(node.func.attr)
    return calls


def _classify(
    index: _Index, cls: _ClassInfo
) -> Tuple[Set[str], Set[str]]:
    """(device_methods, host_methods) for one kernel class, by fixpoint
    over the ``self.``-call graph from the protocol's entry points."""
    flat = index.flattened_methods(cls)

    def expand(seed: Iterable[str], other_seed: Set[str]) -> Set[str]:
        members = {m for m in seed if m in flat}
        changed = True
        while changed:
            changed = False
            for m in sorted(members):
                for callee in _self_calls(flat[m]):
                    if (
                        callee in flat
                        and callee not in members
                        and callee not in other_seed
                    ):
                        members.add(callee)
                        changed = True
        return members

    device = expand(_DEVICE_SEED, set(_HOST_SEED))
    host = expand(_HOST_SEED, set(_DEVICE_SEED))
    ambiguous = device & host
    return device - ambiguous, host - ambiguous


# --------------------------------------------------------------------------
# Statics-key production (host side) and consumption (device side)
# --------------------------------------------------------------------------


def _produced_statics_keys(fn: ast.FunctionDef) -> Set[str]:
    """String keys this host-side method contributes to a statics dict:
    ``dict(...)`` call keywords, dict-literal string keys, and
    ``statics["key"] = ...`` subscript assignments."""
    keys: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and _dotted(node.func) == "dict":
            for kw in node.keywords:
                if kw.arg is not None:
                    keys.add(kw.arg)
        elif isinstance(node, ast.Dict):
            for key in node.keys:
                if isinstance(key, ast.Constant) and isinstance(
                    key.value, str
                ):
                    keys.add(key.value)
        elif isinstance(node, ast.Assign):
            for target in node.targets:
                if (
                    isinstance(target, ast.Subscript)
                    and isinstance(target.slice, ast.Constant)
                    and isinstance(target.slice.value, str)
                ):
                    keys.add(target.slice.value)
    return keys


def _consumed_statics_keys(
    fn: ast.FunctionDef,
) -> List[Tuple[str, int]]:
    """(key, line) for every ``statics[...]`` / ``statics.get(...)``."""
    reads: List[Tuple[str, int]] = []
    for node in ast.walk(fn):
        if (
            isinstance(node, ast.Subscript)
            and isinstance(node.value, ast.Name)
            and node.value.id == "statics"
            and isinstance(node.slice, ast.Constant)
            and isinstance(node.slice.value, str)
        ):
            reads.append((node.slice.value, node.lineno))
        elif (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "get"
            and isinstance(node.func.value, ast.Name)
            and node.func.value.id == "statics"
            and node.args
            and isinstance(node.args[0], ast.Constant)
            and isinstance(node.args[0].value, str)
        ):
            reads.append((node.args[0].value, node.lineno))
    return reads


# --------------------------------------------------------------------------
# Trace-safety dataflow for device-side bodies
# --------------------------------------------------------------------------


class _TraceSafety:
    """Which expressions are Python-level (safe to branch on) inside a
    device-side method. Parameters other than ``self``/``statics`` bind
    traced values; locals inherit safety from their right-hand side in
    source order; ``.shape``-style attributes and ``len()`` of traced
    arrays are static under tracing."""

    def __init__(self, fn: ast.FunctionDef):
        args = fn.args
        names = [
            a.arg
            for a in (
                list(args.posonlyargs) + list(args.args)
                + list(args.kwonlyargs)
            )
        ]
        if args.vararg:
            names.append(args.vararg.arg)
        if args.kwarg:
            names.append(args.kwarg.arg)
        self.unsafe: Set[str] = {
            n for n in names if n not in ("self", "cls", "statics")
        }
        # One pass in source order: assignment targets inherit safety.
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                self._bind(node.targets, node.value)
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                self._bind([node.target], node.value)
            elif isinstance(node, ast.AugAssign):
                self._bind([node.target], node.value)
            elif isinstance(node, ast.For):
                self._bind([node.target], node.iter)
            elif isinstance(node, ast.withitem) and node.optional_vars:
                self._bind([node.optional_vars], node.context_expr)

    def _bind(self, targets: Sequence[ast.AST], value: ast.AST) -> None:
        tainted = not self.is_safe(value)
        stack = list(targets)
        while stack:
            t = stack.pop()
            if isinstance(t, (ast.Tuple, ast.List)):
                stack.extend(t.elts)
            elif isinstance(t, ast.Starred):
                stack.append(t.value)
            elif isinstance(t, ast.Name) and tainted:
                self.unsafe.add(t.id)

    def is_safe(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Constant) or node is None:
            return True
        if isinstance(node, ast.Name):
            return node.id not in self.unsafe
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return True
            return self.is_safe(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_safe(node.value) and self.is_safe(node.slice)
        if isinstance(node, ast.Call):
            fname = _dotted(node.func)
            if fname in _STATIC_CALLS:
                return True
            if isinstance(node.func, ast.Attribute):
                # statics.get(...), cfg.method() style: safety of the root
                return self.is_safe(node.func.value)
            return False
        if isinstance(node, ast.Compare):
            # Key-membership on dict pytrees is Python-level: `"Gt" in aux`
            if all(
                isinstance(op, (ast.In, ast.NotIn)) for op in node.ops
            ) and isinstance(node.left, ast.Constant):
                return True
            return self.is_safe(node.left) and all(
                self.is_safe(c) for c in node.comparators
            )
        if isinstance(node, (ast.BoolOp,)):
            return all(self.is_safe(v) for v in node.values)
        if isinstance(node, ast.BinOp):
            return self.is_safe(node.left) and self.is_safe(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_safe(node.operand)
        if isinstance(node, ast.IfExp):
            return (
                self.is_safe(node.test)
                and self.is_safe(node.body)
                and self.is_safe(node.orelse)
            )
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return all(self.is_safe(e) for e in node.elts)
        if isinstance(node, ast.Dict):
            return all(
                self.is_safe(k) for k in node.keys if k is not None
            ) and all(self.is_safe(v) for v in node.values)
        if isinstance(node, (ast.JoinedStr, ast.FormattedValue)):
            return True
        if isinstance(node, ast.Starred):
            return self.is_safe(node.value)
        if isinstance(node, ast.Slice):
            return all(
                self.is_safe(p)
                for p in (node.lower, node.upper, node.step)
                if p is not None
            )
        return False  # lambdas, comprehensions, await, ...: conservative


# --------------------------------------------------------------------------
# Per-method rule passes
# --------------------------------------------------------------------------


def _check_device_method(
    fn: ast.FunctionDef,
    rel: str,
    produced: Set[str],
    findings: List[Finding],
) -> None:
    safety = _TraceSafety(fn)
    for node in ast.walk(fn):
        # host-rng-in-device-code
        if isinstance(node, ast.Attribute):
            name = _dotted(node) or ""
            if name.startswith(("np.random", "numpy.random", "random.")):
                findings.append(Finding(
                    "host-rng-in-device-code", rel, node.lineno,
                    f"`{name}` in device-side method "
                    f"`{fn.name}` — sample host-side in prepare() "
                    "(DESIGN.md §2)",
                ))
        # callback-in-scan-body
        if isinstance(node, ast.Call):
            name = _dotted(node.func) or ""
            leaf = name.rsplit(".", 1)[-1]
            if name.startswith("jax.debug") or (
                leaf in _CALLBACK_NAMES
                and (name.startswith("jax.") or name == leaf)
            ):
                findings.append(Finding(
                    "callback-in-scan-body", rel, node.lineno,
                    f"`{name}` in device-side method `{fn.name}` — "
                    "callbacks serialize the vmapped scan through the "
                    "host (DESIGN.md §9)",
                ))
            # traced casts: bool()/float()/int()/.item()
            if name in _CAST_CALLS and any(
                not safety.is_safe(a) for a in node.args
            ):
                findings.append(Finding(
                    "traced-python-control-flow", rel, node.lineno,
                    f"`{name}()` on a traced value in `{fn.name}` — "
                    "forces a device sync or a concretization error",
                ))
            if (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in ("item", "tolist")
                and not safety.is_safe(node.func.value)
            ):
                findings.append(Finding(
                    "traced-python-control-flow", rel, node.lineno,
                    f"`.{node.func.attr}()` on a traced value in "
                    f"`{fn.name}`",
                ))
        # traced control flow
        if isinstance(node, (ast.If, ast.While)):
            if not safety.is_safe(node.test):
                kw = "if" if isinstance(node, ast.If) else "while"
                findings.append(Finding(
                    "traced-python-control-flow", rel, node.lineno,
                    f"Python `{kw}` on a traced value in `{fn.name}` — "
                    "branch on statics or use jnp.where/lax.cond "
                    "(DESIGN.md §7)",
                ))
        if isinstance(node, ast.IfExp) and not safety.is_safe(node.test):
            findings.append(Finding(
                "traced-python-control-flow", rel, node.lineno,
                f"conditional expression on a traced value in `{fn.name}`",
            ))
        if isinstance(node, ast.Assert) and not safety.is_safe(node.test):
            findings.append(Finding(
                "traced-python-control-flow", rel, node.lineno,
                f"`assert` on a traced value in `{fn.name}`",
            ))
    # statics-key completeness
    for key, line in _consumed_statics_keys(fn):
        if key not in produced:
            findings.append(Finding(
                "statics-key-not-in-signature", rel, line,
                f"statics[{key!r}] read in `{fn.name}` but no host-side "
                "statics construction produces it — add it to the "
                "prepared statics/static_signature (DESIGN.md §8)",
            ))


def _check_host_method(
    fn: ast.FunctionDef, rel: str, findings: List[Finding]
) -> None:
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and node.id in ("jnp", "jax"):
            findings.append(Finding(
                "device-array-in-host-prepare", rel, node.lineno,
                f"`{node.id}` used in host-side method `{fn.name}` — "
                "the prepare path is pure numpy (DESIGN.md §2)",
            ))


def _check_kernels_module_fn(
    fn: ast.FunctionDef, rel: str, findings: List[Finding]
) -> None:
    """Device-side rules for Pallas kernel modules (everything under
    ``repro/kernels`` executes inside jit/pallas bodies)."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute):
            name = _dotted(node) or ""
            if name.startswith(("np.random", "numpy.random", "random.")):
                findings.append(Finding(
                    "host-rng-in-device-code", rel, node.lineno,
                    f"`{name}` in kernel module function `{fn.name}`",
                ))
        if isinstance(node, ast.Call):
            name = _dotted(node.func) or ""
            leaf = name.rsplit(".", 1)[-1]
            if name.startswith("jax.debug") or (
                leaf in _CALLBACK_NAMES
                and (name.startswith("jax.") or name == leaf)
            ):
                findings.append(Finding(
                    "callback-in-scan-body", rel, node.lineno,
                    f"`{name}` in kernel module function `{fn.name}`",
                ))


# --------------------------------------------------------------------------
# Module-scope rules
# --------------------------------------------------------------------------


def _check_spec_dataclasses(
    tree: ast.Module, rel: str, findings: List[Finding]
) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        deco = None
        for dec in node.decorator_list:
            deco = _is_dataclass_decorator(dec)
            if deco is not None:
                break
        if deco is None:
            continue
        is_spec = (
            node.name.endswith(_SPEC_SUFFIXES) or node.name in _SPEC_NAMES
        ) and node.name not in _SPEC_ALLOWLIST
        if not is_spec:
            continue
        frozen = any(
            kw.arg == "frozen"
            and isinstance(kw.value, ast.Constant)
            and kw.value.value is True
            for kw in deco.keywords
        )
        if not frozen:
            findings.append(Finding(
                "spec-dataclass-not-frozen", rel, node.lineno,
                f"spec dataclass `{node.name}` must be "
                "@dataclasses.dataclass(frozen=True) — it is a jit "
                "cache / grid dedupe key (DESIGN.md §7)",
            ))
        for item in node.body:
            value = None
            if isinstance(item, ast.AnnAssign):
                value = item.value
            elif isinstance(item, ast.Assign):
                value = item.value
            if value is None:
                continue
            if isinstance(value, ast.Call) and (
                _dotted(value.func) or ""
            ).endswith("field"):
                for kw in value.keywords:
                    if kw.arg == "default" and _is_mutable_default(
                        kw.value
                    ):
                        findings.append(Finding(
                            "spec-dataclass-not-frozen", rel,
                            item.lineno,
                            f"mutable field default in `{node.name}`",
                        ))
            elif _is_mutable_default(value):
                findings.append(Finding(
                    "spec-dataclass-not-frozen", rel, item.lineno,
                    f"mutable default in spec dataclass `{node.name}` — "
                    "shared across every instance",
                ))


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------


def _iter_files(paths: Sequence[pathlib.Path]) -> List[pathlib.Path]:
    out: List[pathlib.Path] = []
    for p in paths:
        p = pathlib.Path(p)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        else:
            out.append(p)
    return out


def lint_paths(
    paths: Sequence[pathlib.Path],
    root: Optional[pathlib.Path] = None,
) -> List[Finding]:
    """Lint files/directories; returns findings sorted by location.

    ``root`` (default: CWD if it contains the files) only affects how
    paths are reported. Statics-key production is collected across ALL
    given paths before consumption is checked, so lint the whole tree
    (or one self-contained fixture file) at once.
    """
    files: Dict[pathlib.Path, ast.Module] = {}
    rels: Dict[pathlib.Path, str] = {}
    findings: List[Finding] = []
    for path in _iter_files(paths):
        try:
            rel = str(
                path.relative_to(root) if root is not None else path
            )
        except ValueError:
            rel = str(path)
        rels[path] = rel
        try:
            files[path] = ast.parse(
                path.read_text(encoding="utf-8"), filename=str(path)
            )
        except SyntaxError as exc:
            findings.append(Finding(
                "syntax-error", rel, exc.lineno or 0, str(exc.msg)
            ))
    index = _Index(files)

    # Pass 1: classify every kernel class's methods; collect produced
    # statics keys from all host-side methods.
    device_defs: Dict[int, Tuple[ast.FunctionDef, str]] = {}
    host_defs: Dict[int, Tuple[ast.FunctionDef, str]] = {}
    ambiguous: Set[int] = set()
    produced: Set[str] = set()
    for cls in index.kernel_classes():
        device, host = _classify(index, cls)
        own = cls.methods()
        for mname, fn in own.items():
            key = id(fn)
            if mname in device:
                if key in host_defs:
                    ambiguous.add(key)
                device_defs[key] = (fn, rels[cls.path])
            elif mname in host:
                if key in device_defs:
                    ambiguous.add(key)
                host_defs[key] = (fn, rels[cls.path])
        # Produced keys come from the class's full host-side view
        # (inherited prepare produces keys a subclass's step consumes).
        flat = index.flattened_methods(cls)
        for mname in host:
            produced |= _produced_statics_keys(flat[mname])

    # Pass 2: per-method rules.
    for key, (fn, rel) in device_defs.items():
        if key not in ambiguous:
            _check_device_method(fn, rel, produced, findings)
    for key, (fn, rel) in host_defs.items():
        if key not in ambiguous:
            _check_host_method(fn, rel, findings)

    # Pass 3: module-scope rules.
    for path, tree in files.items():
        rel = rels[path]
        _check_spec_dataclasses(tree, rel, findings)
        if "/kernels/" in str(path).replace("\\", "/"):
            for node in ast.walk(tree):
                if isinstance(node, ast.FunctionDef):
                    _check_kernels_module_fn(node, rel, findings)

    # Dedupe nested-attribute double hits at one location.
    seen: Set[Tuple[str, str, int]] = set()
    unique: List[Finding] = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        loc = (f.rule, f.path, f.line)
        if loc not in seen:
            seen.add(loc)
            unique.append(f)
    return unique
