"""Jaxpr trace audit: structural contracts of the lowered programs.

The AST linter (`repro.analysis.astcheck`) checks what is visible in
source; this module checks what the tracer actually produced. Every
registered kernel is lowered (``jax.make_jaxpr`` — trace only, no
compile) over a representative static-signature grid, and the closed
jaxpr of each group's composed run function is walked recursively:

- ``pallas_calls``: the coded ADMM path must lower through the fused
  Pallas decode-combine + x-update (`kernels.ops.coded_admm_update`,
  DESIGN.md §5); the exact_x path must NOT (it keeps the closed-form
  solve). Audited per grid via ``expect_pallas``.
- ``callbacks``: zero ``pure_callback``/``io_callback``/``debug_*``
  primitives anywhere — a callback inside the vmapped scan serializes
  every iteration through the host and breaks the sharded tier
  (DESIGN.md §9). Asserted unconditionally, not against the baseline.
- ``demotions``: count of f64→f32 ``convert_element_type`` sites. The
  mask path deliberately builds f32 row masks inside the Pallas update
  (PR 5), so the contract is a PINNED count — growth means a new silent
  precision loss — plus an unconditional check that every output aval
  of the composed run stays f64 (``f64_outputs``).
- ``groups``: number of distinct static signatures the grid traces to.
  This is the one-trace-per-group discipline at analysis time: the same
  contract as the benchmark dispatch gate (`benchmarks/check.py`), but
  caught when the statics change, not three PRs later when the
  benchmark regresses. Any growth over the committed baseline fails.

Counts are pinned in ``benchmarks/trace_audit.json`` (refresh with
``python tools/trace_lint.py --update-audit`` after an intentional
change, same workflow as ``make bench-baseline``).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "AuditGrid",
    "AUDIT_GRIDS",
    "audit_report",
    "compare_report",
    "DEFAULT_BASELINE",
]

DEFAULT_BASELINE = (
    pathlib.Path(__file__).resolve().parents[3]
    / "benchmarks"
    / "trace_audit.json"
)

_CALLBACK_PRIMS = {
    "pure_callback",
    "io_callback",
    "debug_callback",
    "debug_print",
}

_ITERS = 12  # enough for the scan to form; tracing cost only


@dataclasses.dataclass(frozen=True)
class AuditGrid:
    """One named audit cell: cases that must share trace structure.

    ``expect_pallas`` — True: every group must contain >=1 pallas_call;
    False: every group must contain none; None: recorded but unasserted.
    ``expect_groups`` — the static-signature group count this grid MUST
    trace to (the one-trace-per-group contract, asserted both against
    this declared value and the committed baseline).
    """

    name: str
    cases: Tuple  # Tuple[Case, ...] — untyped to keep jax imports lazy
    expect_pallas: Optional[bool]
    expect_groups: int


def _cases(method: str, dataset: str = "usps", **axes) -> Tuple:
    """Cartesian Case grid over keyword axes (each value a sequence)."""
    import itertools

    from repro.experiments import Case

    base = dict(method=method, dataset=dataset, N=5, K=3, M=36,
                iters=_ITERS)
    if not axes:
        return (Case(**base),)
    names = list(axes)
    return tuple(
        Case(**{**base, **dict(zip(names, combo))})
        for combo in itertools.product(*(axes[n] for n in names))
    )


def _default_grids() -> Tuple[AuditGrid, ...]:
    # The coded grid mirrors the code_frontier sweep shape (DESIGN.md
    # §11): every family x S x deadline cell shares ONE trace because
    # masks/coeffs are data (PR 5) and MU reconciles via max_statics.
    coded = (
        _cases("csI-ADMM", scheme=("cyclic", "mds"), S=(1, 2))
        + _cases("csI-ADMM", scheme=("approx",), S=(1,),
                 deadline=(3e-4,))
        + _cases("sI-ADMM", S=(0,))
    )
    return (
        AuditGrid("admm_coded", coded, expect_pallas=True,
                  expect_groups=1),
        AuditGrid("admm_exact", _cases("I-ADMM"), expect_pallas=False,
                  expect_groups=1),
        # Event-driven mode (DESIGN.md §13): its own trace via the
        # ("async", cap) signature suffix, still on the Pallas path.
        AuditGrid("admm_async",
                  _cases("csI-ADMM", scheme=("cyclic",), S=(1,),
                         tau_max=(2e-3,)),
                  expect_pallas=True, expect_groups=1),
        # Online controller (DESIGN.md §15): one trace per bandit algo
        # via the ("adaptive", n_arms, algo) suffix — arm schedules are
        # data, and the arm-stacked step still runs the Pallas combine.
        AuditGrid("admm_adaptive",
                  _cases("a-csI-ADMM",
                         arms=((("cyclic", 1, None), ("approx", 1, 3e-4)),),
                         bandit=("ucb1", "exp3")),
                  expect_pallas=True, expect_groups=2),
        AuditGrid("pi_admm", _cases("pI-ADMM", S=(0, 1),
                                    scheme=("cyclic",)),
                  expect_pallas=True, expect_groups=1),
        # compressor is a static (branches the token path in step), so
        # topk and quant are two legitimate trace groups (DESIGN.md §8).
        AuditGrid("cq_admm",
                  _cases("cq-sI-ADMM", compressor=("topk", "quant")),
                  expect_pallas=True, expect_groups=2),
        AuditGrid("walkman", _cases("W-ADMM"), expect_pallas=None,
                  expect_groups=1),
        AuditGrid("gossip_dadmm",
                  _cases("D-ADMM", tau_max=(0.0, 2e-3)),
                  expect_pallas=False, expect_groups=2),
        AuditGrid("gossip_dgd", _cases("DGD", tau_max=(0.0, 2e-3)),
                  expect_pallas=False, expect_groups=2),
        AuditGrid("gossip_extra", _cases("EXTRA", tau_max=(0.0, 2e-3)),
                  expect_pallas=False, expect_groups=2),
    )


# Materialized lazily: building Cases imports repro.experiments (jax).
AUDIT_GRIDS: Dict[str, AuditGrid] = {}


def _grids() -> Dict[str, AuditGrid]:
    if not AUDIT_GRIDS:
        for g in _default_grids():
            AUDIT_GRIDS[g.name] = g
    return AUDIT_GRIDS


# --------------------------------------------------------------------------
# Jaxpr walking
# --------------------------------------------------------------------------


def _sub_jaxprs(params: dict):
    """Every jaxpr nested in an eqn's params (scan/cond/pjit/pallas/...)."""
    import jax.extend.core as jex_core

    def leaves(val):
        if isinstance(val, (jex_core.Jaxpr, jex_core.ClosedJaxpr)):
            yield val
        elif isinstance(val, (list, tuple)):
            for v in val:
                yield from leaves(v)
        elif isinstance(val, dict):
            for v in val.values():
                yield from leaves(v)

    for val in params.values():
        yield from leaves(val)


def _walk(jaxpr, counts: Dict[str, int]) -> None:
    inner = getattr(jaxpr, "jaxpr", jaxpr)  # ClosedJaxpr -> Jaxpr
    for eqn in inner.eqns:
        prim = eqn.primitive.name
        if prim == "pallas_call":
            counts["pallas_calls"] += 1
        if prim in _CALLBACK_PRIMS or "callback" in prim:
            counts["callbacks"] += 1
        if prim == "convert_element_type":
            new = eqn.params.get("new_dtype")
            olds = {str(v.aval.dtype) for v in eqn.invars
                    if hasattr(v.aval, "dtype")}
            if str(new) == "float32" and "float64" in olds:
                counts["demotions"] += 1
        for sub in _sub_jaxprs(eqn.params):
            _walk(sub, counts)


def _audit_group(kernel, case, prob, net) -> Dict[str, object]:
    """Trace ONE representative run of a static group and count."""
    import jax

    from repro.methods import driver

    cfg = kernel.config(case)
    prep = kernel.prepare(prob, net, cfg, case.iters)
    statics = {**prep.statics, **prep.max_statics}
    fn = driver._compose(kernel, driver._statics_key(statics))
    closed = jax.make_jaxpr(fn)(prep.consts, prep.steps)
    counts = {"pallas_calls": 0, "callbacks": 0, "demotions": 0}
    _walk(closed, counts)
    out_dtypes = sorted(
        {
            str(a.dtype)
            for a in closed.out_avals
            if hasattr(a, "dtype") and "float" in str(a.dtype)
        }
    )
    counts["f64_outputs"] = out_dtypes == ["float64"]
    counts["out_dtypes"] = out_dtypes
    return counts


def audit_report(
    names: Optional[Sequence[str]] = None,
) -> Dict[str, dict]:
    """Lower every audit grid and return the structural report.

    Enables x64 (the repo-wide precision contract — tests/conftest.py
    does the same for the suite) before any tracing.
    """
    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.experiments.sweep import _materialize, _signature
    from repro.methods import get_kernel

    report: Dict[str, dict] = {}
    net_cache: dict = {}
    prob_cache: dict = {}
    for grid in _grids().values():
        if names and grid.name not in names:
            continue
        groups: Dict[tuple, Tuple] = {}
        for case in grid.cases:
            net, prob = _materialize(case, net_cache, prob_cache)
            sig = _signature(case, prob)
            groups.setdefault(sig, (case, prob, net))
        entry: Dict[str, object] = {
            "groups": len(groups),
            "expect_pallas": grid.expect_pallas,
            "signatures": {},
        }
        for sig, (case, prob, net) in sorted(
            groups.items(), key=lambda kv: repr(kv[0])
        ):
            kernel = get_kernel(case.method)
            counts = _audit_group(kernel, case, prob, net)
            entry["signatures"][repr(sig)] = counts
        report[grid.name] = entry
    return report


# --------------------------------------------------------------------------
# Gate
# --------------------------------------------------------------------------


def compare_report(
    fresh: Dict[str, dict],
    baseline: Optional[Dict[str, dict]],
) -> Tuple[List[str], List[str]]:
    """(failures, notes) of the fresh report vs declared + pinned
    contracts. ``baseline=None`` checks only the unconditional ones."""
    failures: List[str] = []
    notes: List[str] = []
    grids = _grids()

    for name, entry in fresh.items():
        grid = grids[name]
        sigs = entry["signatures"]
        # Declared group count: the one-trace-per-group contract.
        if entry["groups"] != grid.expect_groups:
            failures.append(
                f"{name}: {entry['groups']} static groups, grid declares "
                f"{grid.expect_groups} — a statics change split (or "
                "merged) the jit trace"
            )
        for sig, counts in sigs.items():
            where = f"{name} {sig}"
            if counts["callbacks"]:
                failures.append(
                    f"{where}: {counts['callbacks']} callback "
                    "primitive(s) in the lowered scan (DESIGN.md §9)"
                )
            if grid.expect_pallas is True and not counts["pallas_calls"]:
                failures.append(
                    f"{where}: no pallas_call — the coded path lost the "
                    "fused decode-combine kernel (DESIGN.md §5)"
                )
            if grid.expect_pallas is False and counts["pallas_calls"]:
                failures.append(
                    f"{where}: unexpected pallas_call on a non-coded "
                    "path"
                )
            if not counts["f64_outputs"]:
                failures.append(
                    f"{where}: float outputs demoted — avals "
                    f"{counts['out_dtypes']} (x64 contract)"
                )

    if baseline is None:
        notes.append("no baseline: unconditional checks only")
        return failures, notes

    for name, base_entry in baseline.items():
        if name not in fresh:
            failures.append(
                f"{name}: pinned in baseline but absent from the fresh "
                "audit — grid removed without --update-audit"
            )
            continue
        entry = fresh[name]
        if entry["groups"] > base_entry["groups"]:
            failures.append(
                f"{name}: static groups grew {base_entry['groups']} -> "
                f"{entry['groups']} (trace/dispatch regression)"
            )
        elif entry["groups"] < base_entry["groups"]:
            notes.append(
                f"{name}: static groups shrank {base_entry['groups']} -> "
                f"{entry['groups']} — improvement; refresh with "
                "--update-audit"
            )
        base_sigs = base_entry["signatures"]
        for sig, counts in entry["signatures"].items():
            base = base_sigs.get(sig)
            if base is None:
                notes.append(f"{name}: NEW signature {sig}")
                continue
            if counts["demotions"] > base["demotions"]:
                failures.append(
                    f"{name} {sig}: f64->f32 demotions grew "
                    f"{base['demotions']} -> {counts['demotions']} — "
                    "new silent precision loss"
                )
            elif counts["demotions"] < base["demotions"]:
                notes.append(
                    f"{name} {sig}: demotions shrank "
                    f"{base['demotions']} -> {counts['demotions']}; "
                    "refresh with --update-audit"
                )
    for name in fresh:
        if name not in baseline:
            notes.append(f"{name}: NEW grid (not yet pinned)")
    return failures, notes


def load_baseline(
    path: pathlib.Path = DEFAULT_BASELINE,
) -> Optional[Dict[str, dict]]:
    if not path.exists():
        return None
    return json.loads(path.read_text(encoding="utf-8"))


def write_baseline(
    report: Dict[str, dict], path: pathlib.Path = DEFAULT_BASELINE
) -> None:
    path.write_text(
        json.dumps(report, indent=2, sort_keys=True) + "\n",
        encoding="utf-8",
    )
