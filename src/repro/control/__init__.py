"""Online control of the code/deadline frontier (DESIGN.md §15).

A bandit controller (UCB1/EXP3, `repro.control.bandit`) rides the
jitted scan carry of the coded-ADMM family and selects one (code
family, S, deadline) arm per iteration from observed iteration
wall-clock alone — arm schedules are pre-threaded data, so an adaptive
run stays ONE dispatch with no retrace (`repro.control.kernel`,
registered as method "a-csI-ADMM").
"""

from .bandit import (
    BANDIT_ALGOS,
    BanditPolicy,
    init_state,
    replay,
    schedule_inputs,
    select,
    update,
)
from .kernel import ADAPTIVE_KERNEL, AdaptiveADMM, AdaptiveRun, device_pulls

__all__ = [
    "BANDIT_ALGOS",
    "BanditPolicy",
    "schedule_inputs",
    "init_state",
    "select",
    "update",
    "replay",
    "AdaptiveRun",
    "AdaptiveADMM",
    "ADAPTIVE_KERNEL",
    "device_pulls",
]
