"""UCB1 / EXP3 bandit policies as scan-carry algebra (DESIGN.md §15).

The online controller of `repro.control.kernel` selects a (code family,
S, deadline) arm every iteration INSIDE a jitted ``lax.scan``. That
forces the policy into a specific shape:

- **State is a fixed pytree** ``{n: (A,), s: (A,)}`` riding the scan
  carry: per-arm pull counts and per-arm score (reward sums for UCB1,
  log-weights for EXP3). No Python control flow depends on it.
- **Everything random or transcendental-in-the-iteration-index is
  pre-threaded host-side** as per-step data, like PR 5's decode
  coefficients and PR 8's staleness slots: EXP3's sampling uniforms
  ``u`` (seed stream ``[8, seed]``) and UCB1's ``log k`` sequence are
  both (iters,) arrays. With ``log`` hoisted off the device, the UCB1
  recursion is built purely from correctly-rounded IEEE ops (div, sqrt,
  mul, add, argmax), so the device pull sequence is bit-reproducible
  against the numpy twin below.
- **The host twin** (:func:`replay`) runs the SAME recursion in numpy
  over the same pre-threaded tables. `prepare` uses it to realize the
  pull-dependent simulated clock (`Prepared.sim_time`) and the async
  staleness/activity schedules before the device ever runs — possible
  because rewards are themselves pre-tabulated per (iteration, arm),
  so the controller's trajectory is a deterministic function of data
  the host already holds.

Both policies maximize cumulative reward in [0, 1]; the controller
feeds them the negative-wall-clock reward surface of
:meth:`repro.core.timing.TimingModel.reward`.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = [
    "BANDIT_ALGOS",
    "BanditPolicy",
    "schedule_inputs",
    "init_state",
    "select",
    "update",
    "replay",
]

BANDIT_ALGOS = ("ucb1", "exp3")

# Seed stream of the controller's sampling uniforms (the host/device
# seed-stream registry: [2]=privacy, [4..6]=timing, [7]=staleness).
UNIFORM_STREAM = 8


@dataclasses.dataclass(frozen=True)
class BanditPolicy:
    """One controller policy: algorithm + its (runtime) hyper-parameters.

    ``c`` is UCB1's confidence-width multiplier; ``eta`` EXP3's learning
    rate and ``gamma`` its uniform-exploration mixture. All three ride
    the device as runtime constants (one (3,) array), so sweeping them
    never retraces — only ``algo`` is a jit static.
    """

    algo: str = "ucb1"
    c: float = 0.5
    eta: float = 0.1
    gamma: float = 0.1

    def __post_init__(self) -> None:
        if self.algo not in BANDIT_ALGOS:
            raise ValueError(
                f"unknown bandit algorithm {self.algo!r}; "
                f"known: {BANDIT_ALGOS}"
            )
        if self.c < 0 or self.eta < 0:
            raise ValueError(
                f"bandit c/eta must be >= 0, got ({self.c}, {self.eta})"
            )
        if not 0 < self.gamma <= 1:
            raise ValueError(
                f"exp3 gamma must be in (0, 1], got {self.gamma}"
            )

    @property
    def params(self) -> np.ndarray:
        """The (3,) runtime-constant parameter vector [c, eta, gamma]."""
        return np.array([self.c, self.eta, self.gamma])


def schedule_inputs(iters: int, seed: int) -> "tuple":
    """(u, logk) pre-threaded per-step controller inputs.

    ``u``: EXP3 sampling uniforms, seed stream ``[UNIFORM_STREAM, seed]``
    (drawn even for UCB1 so switching ``algo`` perturbs nothing else).
    ``logk``: log(1), log(2), ... — UCB1's confidence numerator, hoisted
    host-side so the device recursion never calls a transcendental.
    """
    rng = np.random.default_rng([UNIFORM_STREAM, seed])
    u = rng.random(iters)
    logk = np.log(np.arange(1, iters + 1, dtype=float))
    return u, logk


# -- device side (jnp): one select/update per scan step --------------------


def init_state(n_arms: int, dtype) -> dict:
    """Zeroed controller carry: per-arm pull counts and scores."""
    return dict(
        n=jnp.zeros(n_arms, dtype=dtype), s=jnp.zeros(n_arms, dtype=dtype)
    )


def _exp3_probs(s, par, n_arms: int):
    """EXP3 arm distribution: gamma-mixed softmax of the log-weights."""
    e = jnp.exp(s - jnp.max(s))
    w = e / jnp.sum(e)
    return (1.0 - par[2]) * w + par[2] / n_arms


def select(algo: str, state, u, logk, par, n_arms: int):
    """This iteration's arm (int32 scalar) from the carried state."""
    n, s = state["n"], state["s"]
    if algo == "ucb1":
        k = jnp.sum(n)
        nf = jnp.maximum(n, 1.0)
        idx = s / nf + par[0] * jnp.sqrt(logk / nf)
        arm = jnp.argmax(idx).astype(jnp.int32)
        # Initialization round-robin: pull each arm once before trusting
        # the confidence index.
        return jnp.where(k < n_arms, k.astype(jnp.int32), arm)
    # exp3: invert the mixed-softmax CDF at the pre-threaded uniform.
    cdf = jnp.cumsum(_exp3_probs(s, par, n_arms))
    return jnp.minimum(
        jnp.sum((cdf < u).astype(jnp.int32)), n_arms - 1
    ).astype(jnp.int32)


def update(algo: str, state, arm, reward, par, n_arms: int):
    """Fold the pulled arm's observed reward back into the carry."""
    n = state["n"].at[arm].add(1.0)
    if algo == "ucb1":
        s = state["s"].at[arm].add(reward)
    else:
        # Importance-weighted reward estimate on the sampled arm.
        p = _exp3_probs(state["s"], par, n_arms)
        s = state["s"].at[arm].add(par[1] * reward / p[arm])
    return dict(state, n=n, s=s)


# -- host twin (numpy): the same recursion, sequentially -------------------


def replay(
    policy: BanditPolicy, rewards: np.ndarray, u: np.ndarray,
    logk: np.ndarray,
) -> np.ndarray:
    """Pull sequence of the device controller, computed host-side.

    ``rewards`` is the (iters, n_arms) pre-tabulated reward table, ``u``
    and ``logk`` the :func:`schedule_inputs` arrays. Mirrors
    :func:`select`/:func:`update` operation for operation (same maximum
    conventions, same summation order), so the returned (iters,) int32
    pulls match the device trajectory — asserted bit-for-bit in
    ``tests/test_control.py``.
    """
    iters, n_arms = rewards.shape
    n = np.zeros(n_arms)
    s = np.zeros(n_arms)
    pulls = np.zeros(iters, dtype=np.int32)
    for t in range(iters):
        if policy.algo == "ucb1":
            k = n.sum()
            if k < n_arms:
                arm = int(k)
            else:
                nf = np.maximum(n, 1.0)
                arm = int(np.argmax(s / nf + policy.c * np.sqrt(logk[t] / nf)))
        else:
            e = np.exp(s - np.max(s))
            w = e / np.sum(e)
            p = (1.0 - policy.gamma) * w + policy.gamma / n_arms
            arm = min(int(np.sum(np.cumsum(p) < u[t])), n_arms - 1)
        r = rewards[t, arm]
        n[arm] += 1.0
        if policy.algo == "ucb1":
            s[arm] += r
        else:
            s[arm] += policy.eta * r / p[arm]
        pulls[t] = arm
    return pulls
