"""a-csI-ADMM: online bandit control of the code/deadline frontier.

`AdaptiveADMM` runs the coded incremental-ADMM family under a bandit
controller (DESIGN.md §15): every iteration, carry-resident UCB1/EXP3
state picks one arm from a registered set of (code family, S, deadline)
cells, the step executes that arm's schedule row, and the arm's observed
iteration wall-clock feeds back as reward — all inside ONE jitted scan.

The no-retrace recipe is the schedules-as-data pattern of PR 5/PR 8
taken one axis further: `prepare` builds EVERY arm's full per-iteration
schedule (decode weights, live-partition mask, sub-batch offset,
activity) with `repro.core.admm.make_schedule`, stacks them on an arm
axis, and tabulates the (iters, n_arms) reward surface from the shared
timing draws — the same ECN/link samples back every arm (identical seed
stream), so the table is a true counterfactual: "what would THIS
iteration have cost under THAT arm". The ``_select_arm`` hook then
resolves the controller state into a standard-layout pseudo-``inp``;
the base step algebra, the Pallas combine path, the async pend ring and
the streaming reductions all compose unchanged.

Because rewards are pre-tabulated, the controller trajectory is a
deterministic function of host-known data: `prepare` replays the exact
bandit recursion in numpy (`repro.control.bandit.replay`) to realize
the pull-dependent simulated clock and the async staleness/activity
schedules BEFORE dispatch. The response distribution stays hidden from
the controller — it only ever observes the reward of the arm it pulled.

A single-arm controller degenerates to the static csI-ADMM path: its
`prepare` defers verbatim to `IncrementalADMM` with the arm spliced
into config and timing, so statics, steps, and therefore the jaxpr and
the XLA program are IDENTICAL to the fixed-cell run (bit-identity is
pinned in ``tests/test_control_properties.py``). The static signature
still gains the ``("adaptive", n_arms, algo)`` suffix, so adaptive
cases never merge into a group another kernel would config-build.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np

from repro.core.admm import make_schedule
from repro.core.coding import check_arm_set, make_arm_set
from repro.core.timing import TimingModel
from repro.methods.admm import ADMMRun, IncrementalADMM
from repro.methods.base import Prepared, register

from .bandit import (
    BanditPolicy,
    init_state,
    replay,
    schedule_inputs,
    select,
    update,
)

__all__ = ["AdaptiveRun", "AdaptiveADMM", "ADAPTIVE_KERNEL", "device_pulls"]

# Adaptive step-input layout: 0..5 are the base family's slots (with 1,
# 2, 5 arm-stacked), then the controller's pre-threaded inputs. The
# async ring trio still appends LAST (read via negative indices).
_U, _LOGK, _REWARDS = 6, 7, 8
_N_ADAPTIVE_INPUTS = 9


@dataclasses.dataclass(frozen=True)
class AdaptiveRun(ADMMRun):
    """ADMM run config + the controller's arm set and bandit policy.

    ``cfg.scheme``/``cfg.S`` of the base config are placeholders; the
    live values come from ``arms`` — each a (scheme, S, deadline) cell
    of the code/deadline frontier. ``timing.deadline`` is likewise
    overridden per arm.
    """

    arms: Tuple[Tuple[str, int, Optional[float]], ...] = ()
    policy: BanditPolicy = BanditPolicy()


class AdaptiveADMM(IncrementalADMM):
    """Bandit-controlled csI-ADMM (one kernel, registered "a-csI-ADMM").

    Inherits the entire base family: the adaptive behavior lives in
    `prepare` (arm-stacked schedules + reward table + host replay) and
    the ``_select_arm`` hook (carry-state arm pull + reward feedback).
    ``name`` stays "admm" so the single-arm degenerate case produces
    statics — and a trace — identical to the static family's.
    """

    # -- host side ---------------------------------------------------------

    def config(self, case) -> AdaptiveRun:
        cfg = case.admm_config()
        if cfg.exact_x:
            raise ValueError(
                "adaptive control requires the stochastic coded x-update; "
                "exact_x (I-ADMM) has no code/deadline frontier to select on"
            )
        arms = tuple(
            (scheme, int(S), deadline)
            for scheme, S, deadline in case.arms
        )
        # Arm-set construction fails HERE — at grid construction, with
        # the uniform make_code infeasibility message — never at trace
        # time (DESIGN.md §15).
        check_arm_set(arms, cfg.K)
        for scheme, S, _ in arms:
            dataclasses.replace(cfg, scheme=scheme, S=S).validate()
        return AdaptiveRun(
            cfg,
            case.timing_model(),
            arms=arms,
            policy=BanditPolicy(
                algo=case.bandit,
                c=case.bandit_c,
                eta=case.bandit_eta,
                gamma=case.bandit_gamma,
            ),
        )

    def static_signature(self, problem, run: AdaptiveRun, iters: int) -> tuple:
        # The ("adaptive", n_arms, algo) suffix (DESIGN.md §15) applies
        # to the single-arm degenerate too: its statics/trace are the
        # static family's, but it must never merge into a group whose
        # first case another kernel would config-build.
        return super().static_signature(problem, run, iters) + (
            "adaptive", len(run.arms), run.policy.algo,
        )

    def _degenerate(self, run: AdaptiveRun) -> ADMMRun:
        """The static run a single-arm controller is bit-identical to."""
        scheme, S, deadline = run.arms[0]
        timing = run.timing or TimingModel()
        return ADMMRun(
            dataclasses.replace(run.cfg, scheme=scheme, S=S),
            dataclasses.replace(timing, deadline=deadline),
        )

    def _arm_tables(self, problem, net, run: AdaptiveRun, iters: int) -> dict:
        """Host-side arm-stacked schedules, reward table, and replay.

        All arms consume the SAME timing seed streams (`make_schedule`
        re-draws with the run seed per arm, and the draws depend only on
        (iters, K, seed)), so row k of every arm's schedule describes
        the same realized fleet under a different code/deadline choice.
        """
        cfg, timing = run.cfg, run.timing or TimingModel()
        codes = make_arm_set(run.arms, cfg.K, seed=cfg.seed)
        dt = problem.O.dtype
        comm = self._comm_per_iter(run, problem)
        scheds, W_a, mask_a, dt_a = [], [], [], []
        for (scheme, S, deadline), code in zip(run.arms, codes):
            acfg = dataclasses.replace(cfg, scheme=scheme, S=S)
            acfg.validate()
            sched = make_schedule(
                acfg, net, code,
                dataclasses.replace(timing, deadline=deadline),
                iters, problem.b,
            )
            scheds.append(sched)
            W_a.append((sched["decode"].astype(dt) @ code.B.astype(dt)) / cfg.K)
            cover = np.abs(code.B) > 1e-12
            mask_a.append(
                ((sched["alive"].astype(dt) @ cover.astype(dt)) > 0).astype(dt)
            )
            dt_a.append(sched["resp_time"] + sched["link_time"] * comm)
        dt_arm = np.stack(dt_a, axis=1)  # (iters, A) observed wall-clock
        rewards = timing.reward(dt_arm).astype(dt)
        u, logk = schedule_inputs(iters, cfg.seed)
        pulls = replay(run.policy, np.asarray(rewards, float), u, logk)
        return dict(
            scheds=scheds,
            W=np.stack(W_a, axis=1),  # (iters, A, K)
            wmask=np.stack(mask_a, axis=1),  # (iters, A)
            offsets=np.stack(
                [s["offsets"] for s in scheds], axis=1
            ).astype(np.int32),
            act=np.stack([s["act"] for s in scheds], axis=1),
            mu_arms=np.array([s["mu"] for s in scheds], dtype=np.int32),
            dt_arm=dt_arm,
            rewards=rewards,
            u=u,
            logk=logk,
            pulls=pulls,
            sim_time=np.cumsum(dt_arm[np.arange(iters), pulls]),
        )

    def prepare(self, problem, net, run: AdaptiveRun, iters: int):
        if len(run.arms) == 1:
            # Degenerate controller: EXACTLY the static path — same
            # consts, steps, statics, trace, bits.
            return super().prepare(problem, net, self._degenerate(run), iters)
        cfg, timing = run.cfg, run.timing or TimingModel()
        tab = self._arm_tables(problem, net, run, iters)
        dt = problem.O.dtype
        sched0 = tab["scheds"][0]
        # NOTE: slots 6..8 are reserved for the controller inputs, so
        # the adaptive kernel does not take `_extra_steps` subclass
        # extras (privacy/compression are separate registry entries).
        steps = (
            sched0["agents"],
            tab["offsets"],
            tab["W"],
            sched0["tau"].astype(dt),
            sched0["gamma"].astype(dt),
            tab["wmask"],
            tab["u"].astype(dt),
            tab["logk"].astype(dt),
            tab["rewards"],
        )
        statics = dict(
            self._statics(run, problem, iters, sched0),
            ADAPTIVE=True,
            A=len(run.arms),
            ALGO=run.policy.algo,
        )
        sim_time = tab["sim_time"]
        if timing.is_async:
            # Same ring-slot construction as the base async path
            # (DESIGN.md §13), but on the REALIZED pull-dependent clock,
            # and with the pulled arm's activity gate (a churned pattern
            # may be decodable under one arm and not another).
            D = timing.staleness_cap
            delta = timing.staleness_steps(
                sim_time, np.random.default_rng([7, cfg.seed])
            )
            k = np.arange(iters)
            act = tab["act"][k, tab["pulls"]]
            steps = steps + (
                ((k + delta) % D).astype(np.int32),
                (k % D).astype(np.int32),
                act.astype(dt),
            )
            statics = dict(statics, ASYNC=True, D=D)
        return Prepared(
            consts=(
                problem.O,
                problem.T,
                problem.x_star().astype(dt),
                problem.O_test,
                problem.T_test,
                np.asarray(cfg.rho, dtype=dt),
                np.asarray(int(tab["mu_arms"].max()), dtype=np.int32),
                tab["mu_arms"],
                run.policy.params.astype(dt),
            ),
            steps=steps,
            statics=statics,
            max_statics=dict(MU=int(tab["mu_arms"].max())),
            comm=np.cumsum(np.full(iters, self._comm_per_iter(run, problem))),
            sim_time=sim_time,
        )

    def max_statics_bound(self, problem, run: AdaptiveRun, iters: int) -> dict:
        if len(run.arms) == 1:
            return super().max_statics_bound(
                problem, self._degenerate(run), iters
            )
        return dict(
            MU=max(
                dataclasses.replace(run.cfg, scheme=scheme, S=S).M_bar
                // run.cfg.K
                for scheme, S, _ in run.arms
            )
        )

    # -- device side -------------------------------------------------------

    def setup(self, consts, statics):
        aux = super().setup(consts[:7], statics)
        if statics.get("ADAPTIVE"):
            aux = dict(aux, mu_arms=consts[7], bpar=consts[8])
        return aux

    def init(self, aux, statics):
        state = super().init(aux, statics)
        if statics.get("ADAPTIVE"):
            state = dict(
                state, bandit=init_state(statics["A"], aux["dtype"])
            )
        return state

    def _select_arm(self, state, inp, aux, statics):
        if not statics.get("ADAPTIVE"):
            return state, inp, aux
        algo, n_arms = statics["ALGO"], statics["A"]
        arm = select(
            algo, state["bandit"], inp[_U], inp[_LOGK], aux["bpar"], n_arms
        )
        state = dict(
            state,
            bandit=update(
                algo, state["bandit"], arm, inp[_REWARDS][arm],
                aux["bpar"], n_arms,
            ),
        )
        # The pulled arm's sub-batch size mu: re-derive the gather mask
        # and normalization the base setup fixed from the scalar bound.
        mu_k = aux["mu_arms"][arm]
        aux = dict(
            aux,
            valid=(aux["rows"] < mu_k).astype(aux["dtype"]),
            inv_mu=1.0 / mu_k.astype(aux["dtype"]),
        )
        # Standard-layout pseudo-inp: the live arm's schedule row in
        # slots 0..5, controller slots dropped, async trio (if any)
        # preserved at the end.
        sel = (
            inp[0], inp[1][arm], inp[2][arm], inp[3], inp[4], inp[5][arm],
        )
        return state, sel + tuple(inp[_N_ADAPTIVE_INPUTS:]), aux


ADAPTIVE_KERNEL = register(AdaptiveADMM(), "a-csI-ADMM")


def device_pulls(problem, net, run: AdaptiveRun, iters: int) -> np.ndarray:
    """The DEVICE controller's realized pull sequence (test/diagnostic).

    Composes the same scan the drivers run but emits each iteration's
    selected arm, recomputed from the pre-update carry exactly as
    ``_select_arm`` does (pure function of the same inputs). Pinned
    bit-equal to the host `replay` in ``tests/test_control.py``.
    """
    if len(run.arms) < 2:
        raise ValueError("device_pulls needs a multi-arm adaptive run")
    kernel = ADAPTIVE_KERNEL
    prep = kernel.prepare(problem, net, run, iters)
    statics = dict(prep.statics, **prep.max_statics)

    def fn(consts, steps):
        aux = kernel.setup(consts, statics)

        def body(state, inp):
            arm = select(
                statics["ALGO"], state["bandit"], inp[_U], inp[_LOGK],
                aux["bpar"], statics["A"],
            )
            state, _ = kernel.step(state, inp, aux, statics)
            return state, arm

        return jax.lax.scan(body, kernel.init(aux, statics), steps)[1]

    return np.asarray(jax.jit(fn)(prep.consts, prep.steps), dtype=np.int32)
