"""Serving launcher: batched prefill + decode loop.

Serves a (smoke-sized on CPU) model: builds a batch of prompts, prefills
once, then streams greedy decode steps from the KV/state cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 64 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import get_model


def serve(model, batch: int, prompt_len: int, new_tokens: int, seed: int = 0):
    cfg = model.cfg
    rng = jax.random.key(seed)
    params = model.init(rng)
    prompts = jax.random.randint(
        jax.random.key(seed + 1), (batch, prompt_len), 0, cfg.vocab, jnp.int32
    )
    kwargs = {}
    if cfg.modality == "vision_stub":
        kwargs["extra_embeds"] = (
            jnp.ones((batch, 16, cfg.d_model), cfg.jnp_dtype) * 0.01
        )
    elif cfg.modality == "audio_stub":
        kwargs["extra_embeds"] = (
            jnp.ones((batch, cfg.encoder_positions, cfg.d_model), cfg.jnp_dtype)
            * 0.01
        )

    t0 = time.time()
    logits, cache = model.prefill(
        params, prompts, extra_slots=new_tokens, **kwargs
    )
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    decode = jax.jit(model.decode)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.time()
    for _ in range(new_tokens - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    tokens = jnp.concatenate(out, axis=1)
    return {
        "tokens": np.asarray(tokens),
        "prefill_s": t_prefill,
        "decode_s_per_tok": t_decode / max(new_tokens - 1, 1),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    r = serve(model, args.batch, args.prompt_len, args.new_tokens, args.seed)
    print(
        f"served batch={args.batch} prompt={args.prompt_len} "
        f"new={args.new_tokens}: prefill {r['prefill_s']:.2f}s, "
        f"{r['decode_s_per_tok'] * 1000:.1f} ms/token"
    )
    print("first sequence:", r["tokens"][0][:16], "...")
    return r


if __name__ == "__main__":
    main()
