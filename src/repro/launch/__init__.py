"""Launchers: production meshes, multi-pod dry-run, train/serve CLIs."""

from .mesh import HW, make_production_mesh

__all__ = ["make_production_mesh", "HW"]
