"""Production meshes (TPU v5e).

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run forces 512 host devices BEFORE calling these).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16, 16) ("data", "model") = 256 chips.
    Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


class HW:
    """TPU v5e roofline constants (per chip)."""

    PEAK_BF16 = 197e12  # FLOP/s
    HBM_BW = 819e9  # B/s
    ICI_BW = 50e9  # B/s per link
    HBM_BYTES = 16 * 2**30
