"""Trip-count-aware cost analysis over compiled HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` visits every computation
ONCE — a ``lax.scan`` over 126 layers reports 1/126th of the real FLOPs, and
collectives inside the loop (FSDP all-gathers!) are similarly dropped. This
module re-derives FLOPs / HBM bytes / collective bytes from ``as_text()``
with while-loop multipliers:

  - dots:      2 * prod(result) * prod(contracting dims)    (FMA = 2)
  - convs:     2 * prod(result) * prod(kernel)/out_features
  - reduces:   1 * prod(input)
  - eltwise:   1 * prod(result) for arithmetic/transcendental ops
  - bytes:     operands + result of every *top-level* instruction
               (post-fusion, the standard HBM-roundtrip approximation;
               fusion-internal instructions cost flops only)
  - while:     body and cond costs multiplied by the trip count, parsed
               from the loop condition's `constant(N)` + compare(LT)
               (lax.scan/fori_loop canonical form). Nested whiles compose.

All numbers are PER DEVICE (the compiled module is the SPMD-partitioned
per-device program).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

# ops that are free (no flops, no HBM traffic of their own)
_FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "opt-barrier", "partition-id", "replica-id", "iota",
    "broadcast", "reshape",
}

_ELTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "tanh", "log", "negate", "rsqrt", "sqrt", "abs", "sign",
    "floor", "ceil", "cosine", "sine", "logistic", "select", "compare",
    "and", "or", "not", "xor", "clamp", "remainder",
    "exponential-minus-one", "log-plus-one", "cbrt", "atan2", "erf",
}

# dtype conversions move bytes, not FLOPs — counting them as arithmetic
# inflated decode-shape "compute" ~30x (the bf16->f32 cast of a whole KV
# cache is pure bandwidth). They still participate in the bytes model via
# the fusions that contain them.
_ZERO_FLOP_ELTWISE = {"convert", "copy"}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.*)\s*\{$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """(elements, bytes) summed over every array shape in a type string."""
    elems = tot = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        tot += n * _DTYPE_BYTES[dt]
    return elems, tot


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    result_type: str
    operands: List[str]  # operand %names
    attrs: str  # everything after the closing paren
    raw: str


@dataclasses.dataclass
class _Comp:
    name: str
    params: Dict[str, str]  # param name -> type str
    instrs: List[_Instr]
    shapes: Dict[str, str]  # %name -> type str


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVE_OPS}
    )
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVE_OPS}
    )
    unknown_trip_whiles: int = 0

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "per_collective": dict(self.per_collective),
            "collective_counts": dict(self.collective_counts),
            "unknown_trip_whiles": self.unknown_trip_whiles,
        }


def _parse_computations(text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and ("->" in line):
                name = m.group(2)
                params = {}
                for p in m.group(3).split(","):
                    p = p.strip()
                    if ":" in p:
                        pname, ptype = p.split(":", 1)
                        params[pname.strip().lstrip("%")] = ptype.strip()
                cur = _Comp(name, params, [], {})
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result type = prefix of rhs up to the op name: "<type> <op>(...".
        # Tuple types contain nested parens/commas — scan balanced.
        if rhs.startswith("("):
            depth = 0
            end = 0
            for i, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i + 1
                        break
            rtype = rhs[:end]
            tail = rhs[end:].lstrip()
        else:
            sp = rhs.find(" ")
            if sp < 0:
                continue
            rtype = rhs[:sp]
            tail = rhs[sp + 1:].lstrip()
        om = re.match(r"([\w\-]+)\(", tail)
        if not om:
            continue
        op = om.group(1)
        rest = tail[om.end():]
        depth = 1
        args_chars = []
        close = len(rest) - 1  # malformed line: attrs degrade to ""
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    close = i
                    break
            args_chars.append(ch)
        attrs = rest[close + 1:]
        arg_str = "".join(args_chars)
        operands = re.findall(r"%([\w.\-]+)", arg_str)
        instr = _Instr(name, op, rtype, operands, attrs, rhs)
        cur.instrs.append(instr)
        cur.shapes[name] = rtype
    return comps


def _operand_type(comp: _Comp, name: str) -> str:
    if name in comp.shapes:
        return comp.shapes[name]
    if name in comp.params:
        return comp.params[name]
    return ""


def _called(attrs: str, key: str) -> Optional[str]:
    m = re.search(rf"{key}=%([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _trip_count(comps: Dict[str, _Comp], cond_name: str) -> Optional[int]:
    """Max s32 constant in the cond computation (lax.scan canonical form)."""
    cond = comps.get(cond_name)
    if cond is None:
        return None
    best = None
    stack = [cond]
    seen = set()
    while stack:
        c = stack.pop()
        if c.name in seen:
            continue
        seen.add(c.name)
        for ins in c.instrs:
            if ins.op == "constant" and ins.result_type.startswith("s32"):
                m = re.search(r"constant\((-?\d+)\)", ins.raw)
                if m:
                    v = int(m.group(1))
                    best = v if best is None else max(best, v)
            callee = _called(ins.attrs, "calls") or _called(ins.attrs, "to_apply")
            if callee and callee in comps:
                stack.append(comps[callee])
    return best


def _dot_flops(comp: _Comp, ins: _Instr) -> float:
    relems, _ = _shape_elems_bytes(ins.result_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    contract = 1
    if m and ins.operands:
        lhs_type = _operand_type(comp, ins.operands[0])
        sm = _SHAPE_RE.search(lhs_type)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contract *= dims[int(idx)]
    return 2.0 * relems * contract


def _conv_flops(comp: _Comp, ins: _Instr) -> float:
    relems, _ = _shape_elems_bytes(ins.result_type)
    if len(ins.operands) < 2:
        return 2.0 * relems
    rhs_type = _operand_type(comp, ins.operands[1])
    kelems, _ = _shape_elems_bytes(rhs_type)
    # out feature count = feature dim of result per dim_labels (fallback:
    # last dim of kernel)
    out_f = 1
    dm = re.search(r"dim_labels=[^ ,]*->(\w+)", ins.attrs)
    rm = _SHAPE_RE.search(ins.result_type)
    if dm and rm:
        out_labels = dm.group(1)
        dims = [int(d) for d in rm.group(2).split(",") if d]
        if "f" in out_labels and len(dims) == len(out_labels):
            out_f = dims[out_labels.index("f")]
    else:
        km = _SHAPE_RE.search(rhs_type)
        if km:
            kd = [int(d) for d in km.group(2).split(",") if d]
            out_f = kd[-1] if kd else 1
    return 2.0 * relems * max(kelems // max(out_f, 1), 1)


_SLICING = {"dynamic-slice", "gather", "slice"}


_REGION_OPS = _SLICING | {"dynamic-update-slice"}


def _fusion_bytes(comps: Dict[str, _Comp], comp: _Comp, operand_types: List[str]) -> float:
    """HBM bytes of one fusion execution.

    Region-aware: a parameter whose every use is a slicing op
    (dynamic-slice / gather / slice / the buffer side of a
    dynamic-update-slice) is only touched at the accessed region — the
    layer-scan reads ONE layer's weights and writes ONE layer's gradient
    per iteration even though the stacked (L, ...) array is the operand.
    Other parameters count in full; the root result counts once unless the
    root is itself a region write (already charged).
    """
    total = 0.0
    # region contributions from slicing ops inside the fusion
    for ins in comp.instrs:
        if ins.op in _SLICING:
            total += _shape_elems_bytes(ins.result_type)[1]
        elif ins.op == "dynamic-update-slice" and len(ins.operands) >= 2:
            upd_t = _operand_type(comp, ins.operands[1])
            total += 2 * _shape_elems_bytes(upd_t)[1]
    # full reads for params not exclusively consumed by region ops
    pnames = list(comp.params)
    for idx, pname in enumerate(pnames):
        uses = [ins for ins in comp.instrs if pname in ins.operands]
        buffer_only = all(
            u.op in _SLICING
            or (u.op == "dynamic-update-slice" and u.operands and u.operands[0] == pname)
            for u in uses
        )
        if uses and buffer_only:
            continue  # charged via the region ops above
        ptype = (
            operand_types[idx] if idx < len(operand_types) else comp.params[pname]
        )
        total += _shape_elems_bytes(ptype)[1]
    # root write (skip if the root chain ends in a region write)
    root = comp.instrs[-1] if comp.instrs else None
    if root is not None:
        r = root
        # peel bitcast/tuple wrappers
        seen = 0
        while r.op in ("bitcast", "copy") and r.operands and seen < 4:
            nxt = next((i for i in comp.instrs if i.name == r.operands[0]), None)
            if nxt is None:
                break
            r = nxt
            seen += 1
        if r.op not in _REGION_OPS:
            total += _shape_elems_bytes(root.result_type)[1]
    return total


def _accumulate(
    comps: Dict[str, _Comp],
    comp: _Comp,
    mult: float,
    top_level: bool,
    cost: HloCost,
) -> None:
    for ins in comp.instrs:
        op = ins.op
        if op in _FREE:
            continue
        # ---- flops ----
        if op == "dot":
            cost.flops += mult * _dot_flops(comp, ins)
        elif op == "convolution":
            cost.flops += mult * _conv_flops(comp, ins)
        elif op in ("reduce", "reduce-window"):
            ielems = 0
            if ins.operands:
                ielems, _ = _shape_elems_bytes(
                    _operand_type(comp, ins.operands[0])
                )
            cost.flops += mult * ielems
        elif op in _ELTWISE and op not in _ZERO_FLOP_ELTWISE:
            relems, _ = _shape_elems_bytes(ins.result_type)
            cost.flops += mult * relems
        # ---- control flow / calls ----
        if op == "while":
            body = _called(ins.attrs, "body")
            cond = _called(ins.attrs, "condition")
            # primary: XLA's own annotation backend_config=
            #   {"known_trip_count":{"n":"8"}, ...}
            trip = None
            tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.attrs)
            if tm:
                trip = int(tm.group(1))
            if trip is None and cond:
                trip = _trip_count(comps, cond)
            if trip is None or trip <= 0:
                trip = 1
                cost.unknown_trip_whiles += 1
            if body and body in comps:
                _accumulate(comps, comps[body], mult * trip, top_level, cost)
            if cond and cond in comps:
                _accumulate(comps, comps[cond], mult * trip, top_level, cost)
            continue  # while itself has no cost
        if op == "conditional":
            for key in ("true_computation", "false_computation"):
                c = _called(ins.attrs, key)
                if c and c in comps:
                    _accumulate(comps, comps[c], mult, top_level, cost)
            for c in re.findall(r"branch_computations=\{([^}]*)\}", ins.attrs):
                for b in re.findall(r"%([\w.\-]+)", c):
                    if b in comps:
                        _accumulate(comps, comps[b], mult, top_level, cost)
            continue
        fusion_like = op in ("fusion", "call", "async-start")
        if fusion_like:
            callee = _called(ins.attrs, "calls") or _called(ins.attrs, "to_apply")
            if callee and callee in comps:
                # flops inside; bytes via slicing-aware fusion accounting
                _accumulate(comps, comps[callee], mult, False, cost)
                if top_level and op == "fusion":
                    ot = [_operand_type(comp, o) for o in ins.operands]
                    cost.bytes += mult * _fusion_bytes(comps, comps[callee], ot)
        # ---- bytes (top-level instructions only: post-fusion HBM traffic)
        if top_level and not (fusion_like and op == "fusion"):
            if op in _SLICING:
                # reads only the slice; writes the result
                rb = _shape_elems_bytes(ins.result_type)[1]
                cost.bytes += mult * 2 * rb
            elif op in ("dynamic-update-slice", "scatter"):
                # touches only the updated region (update operand is last
                # data operand: dus(buf, update, idx...), scatter(op, idx, upd))
                upd = None
                if op == "dynamic-update-slice" and len(ins.operands) >= 2:
                    upd = ins.operands[1]
                elif op == "scatter" and len(ins.operands) >= 3:
                    upd = ins.operands[2]
                ub = (
                    _shape_elems_bytes(_operand_type(comp, upd))[1]
                    if upd
                    else 0
                )
                cost.bytes += mult * 2 * ub
            elif op in _ELTWISE:
                # Idealized-fusion model: the dry-run compiles with the CPU
                # backend, whose fusion is far less aggressive than TPU's.
                # A TPU compile fuses elementwise chains into their
                # consumers, so standalone elementwise ops are modeled as
                # free; their tensors are charged at the materializing ops
                # (dots, reduces, copies, collectives, fusions) around them.
                pass
            else:
                ob = sum(
                    _shape_elems_bytes(_operand_type(comp, o))[1]
                    for o in ins.operands
                )
                rb = _shape_elems_bytes(ins.result_type)[1]
                cost.bytes += mult * (ob + rb)
        # ---- collectives ----
        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVE_OPS:
            ob = sum(
                _shape_elems_bytes(_operand_type(comp, o))[1]
                for o in ins.operands
            )
            if ob == 0:
                ob = _shape_elems_bytes(ins.result_type)[1]
            cost.per_collective[base] += mult * ob
            cost.collective_counts[base] += mult
            cost.collective_bytes += mult * ob


def analyze_hlo(text: str) -> HloCost:
    """Per-device FLOPs / HBM bytes / collective bytes with loop multipliers."""
    comps = _parse_computations(text)
    cost = HloCost()
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line.strip())
            if m:
                entry = m.group(2)
            break
    if entry is None:
        # fall back: computation named main-ish
        for name in comps:
            if name.startswith("main"):
                entry = name
                break
    if entry is None or entry not in comps:
        raise ValueError("could not locate ENTRY computation in HLO text")
    _accumulate(comps, comps[entry], 1.0, True, cost)
    return cost
