"""Training launcher.

Runs REAL steps (CPU-sized configs train here; full configs are exercised
via the dry-run). Two modes:

  plain      — standard Adam training (PlainRuntime)
  consensus  — the paper's csI-ADMM across simulated agents
               (ConsensusRuntime; straggler events sampled per step)

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 50 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --mode consensus --agents 2 --ecns 4 --stragglers 1 --steps 100
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_step
from repro.configs import get_config, get_smoke_config
from repro.data import agent_token_streams, make_lm_batch
from repro.distributed import ConsensusConfig, ConsensusRuntime, PlainRuntime
from repro.models import get_model
from repro.optim import adam_init


def _mesh_1dev():
    return jax.make_mesh((1, 1, 1), ("agent", "data", "model"))


def run_plain(model, args) -> dict:
    rt = PlainRuntime(model, _mesh_1dev(), lr=args.lr)
    params = model.init(jax.random.key(args.seed))
    state = {"params": params, "opt": adam_init(params)}
    step = jax.jit(rt.train_step)
    stream = agent_token_streams(1, model.cfg.vocab, seed=args.seed)[0]
    losses = []
    t0 = time.time()
    for k in range(args.steps):
        batch = jax.tree.map(
            jnp.asarray, make_lm_batch(stream, args.batch, args.seq)
        )
        if model.cfg.modality == "vision_stub":
            batch["extra_embeds"] = jnp.ones(
                (args.batch, 16, model.cfg.d_model), model.cfg.jnp_dtype
            ) * 0.01
        elif model.cfg.modality == "audio_stub":
            batch["extra_embeds"] = jnp.ones(
                (args.batch, model.cfg.encoder_positions, model.cfg.d_model),
                model.cfg.jnp_dtype,
            ) * 0.01
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        if k % args.log_every == 0 or k == args.steps - 1:
            print(
                f"step {k:5d}  loss {losses[-1]:.4f}  "
                f"({(time.time() - t0) / (k + 1):.2f}s/step)",
                flush=True,
            )
        if args.ckpt_dir and (k + 1) % args.ckpt_every == 0:
            save_step(args.ckpt_dir, k + 1, state["params"])
    return {"losses": losses, "state": state}


def run_consensus(model, args) -> dict:
    ccfg = ConsensusConfig(
        n_agents=args.agents,
        K=args.ecns,
        S=args.stragglers,
        scheme=args.scheme if args.stragglers else "uncoded",
        rho=args.rho,
        c_tau=args.c_tau,
        c_gamma=args.c_gamma,
        mode=args.consensus_mode,
        seed=args.seed,
    )
    rt = ConsensusRuntime(model, ccfg, _mesh_1dev())
    state = rt.init_state(jax.random.key(args.seed))
    step = jax.jit(rt.train_step)
    code = ccfg.code()
    sup = [code.support(j) for j in range(args.ecns)]
    # disjoint stream per agent (paper's allocation)
    streams = agent_token_streams(args.agents, model.cfg.vocab, seed=args.seed)
    rng = np.random.default_rng(args.seed + 7)
    A, K, S1 = args.agents, args.ecns, args.stragglers + 1
    P_rows = max(args.batch // (A * K * S1), 1)
    losses, residuals = [], []
    t0 = time.time()
    for k in range(args.steps):
        # coded allocation: sample each agent's K distinct partitions, then
        # lay out partition t on every ECN whose support contains it.
        rows = []
        for a in range(A):
            parts = [
                make_lm_batch(streams[a], P_rows, args.seq) for _ in range(K)
            ]
            for j in range(K):
                for t in sup[j]:
                    rows.append(parts[t])
        batch = {
            key: jnp.concatenate([r[key] for r in rows], axis=0)
            for key in rows[0]
        }
        alive = np.ones((A, K), bool)
        for a in range(A):  # straggler event: drop up to S random ECNs
            dead = rng.choice(K, size=args.stragglers, replace=False)
            alive[a, dead] = False
        state, metrics = step(state, batch, jnp.asarray(alive))
        losses.append(float(metrics["loss"]))
        residuals.append(float(metrics["consensus_residual"]))
        if k % args.log_every == 0 or k == args.steps - 1:
            print(
                f"step {k:5d}  loss {losses[-1]:.4f}  "
                f"residual {residuals[-1]:.3e}  "
                f"({(time.time() - t0) / (k + 1):.2f}s/step)",
                flush=True,
            )
        if args.ckpt_dir and (k + 1) % args.ckpt_every == 0:
            save_step(args.ckpt_dir, k + 1, state["z"])
    return {"losses": losses, "residuals": residuals, "state": state}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--mode", choices=("plain", "consensus"), default="plain")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    # consensus
    ap.add_argument("--agents", type=int, default=2)
    ap.add_argument("--ecns", type=int, default=4)
    ap.add_argument("--stragglers", type=int, default=1)
    # NN-scale defaults: the x-update's effective step is 1/(rho + tau^k),
    # so c_tau ~ 20 gives ~0.05 at k=1 decaying as 1/sqrt(k) (the paper's
    # least-squares settings rho=1, c_tau~0.1 diverge on NN losses).
    ap.add_argument("--scheme", default="cyclic")
    ap.add_argument("--rho", type=float, default=1.0)
    ap.add_argument("--c-tau", type=float, default=20.0)
    ap.add_argument("--c-gamma", type=float, default=0.1)
    ap.add_argument(
        "--consensus-mode", choices=("incremental", "parallel"), default="incremental"
    )
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    print(
        f"training {args.arch} ({'smoke' if args.smoke else 'full'}) "
        f"mode={args.mode} params={cfg.param_count():,}"
    )
    if args.mode == "plain":
        out = run_plain(model, args)
    else:
        out = run_consensus(model, args)
    first, last = out["losses"][0], out["losses"][-1]
    print(f"loss: {first:.4f} -> {last:.4f}")
    return out


if __name__ == "__main__":
    main()
