import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# init. Only this module forces 512 placeholder devices — tests and
# benchmarks see the single real CPU device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

For each combination this prints/collects:
  - memory_analysis()  (per-device argument/output/temp/peak bytes),
  - cost_analysis()    (XLA's numbers, recorded for reference — they count
    lax.scan bodies ONCE and so under-report layer-stacked models),
  - repro.launch.hlo_cost.analyze_hlo — trip-count-aware per-device FLOPs /
    HBM bytes / collective bytes (all-gather, all-reduce, reduce-scatter,
    all-to-all, collective-permute), the numbers the roofline uses,
and writes one JSON record per combo consumed by benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --consensus
"""

import argparse
import json
import sys
import time
import traceback
from typing import Optional

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.configs.registry import input_specs, shape_applicable
from repro.configs.shapes import SHAPES
from repro.distributed import ConsensusConfig, ConsensusRuntime, PlainRuntime
from repro.distributed.consensus import make_consensus_mesh
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import HW, make_production_mesh
from repro.models import get_model


def roofline_terms(
    flops_dev: float, bytes_dev: float, coll_bytes_dev: float
) -> dict:
    """The three roofline terms in seconds. Inputs are PER-DEVICE numbers
    (the compiled module is the SPMD per-device program), so no further
    division by chip count: t = per_device_work / per_chip_rate, which
    equals global_work / (chips * rate)."""
    terms = {
        "compute_s": flops_dev / HW.PEAK_BF16,
        "memory_s": bytes_dev / HW.HBM_BW,
        "collective_s": coll_bytes_dev / HW.ICI_BW,
    }
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    )
    return terms


def run_one(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    consensus: bool = False,
    verbose: bool = True,
    opts: str = "",
    consensus_mode: str = "incremental",
) -> Optional[dict]:
    """opts: comma list of config overrides, e.g. "remat=full,attn_block_kv=2048"."""
    import dataclasses

    cfg = get_config(arch)
    if opts:
        overrides = {}
        for kv in opts.split(","):
            k, v = kv.split("=", 1)
            cur = getattr(cfg, k)
            overrides[k] = type(cur)(v) if cur is not None else v
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    skip = shape_applicable(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": skip}
    model = get_model(cfg)
    t0 = time.time()

    if consensus:
        if shape.kind != "train":
            return None
        mesh = make_consensus_mesh(2 if multi_pod else 4, multi_pod=multi_pod)
        ccfg = ConsensusConfig(
            n_agents=2 if multi_pod else 4, mode=consensus_mode
        )
        rt = ConsensusRuntime(model, ccfg, mesh)
        params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        batch = input_specs(cfg, shape)
        lowered = rt.lower_train_step(batch, params_shape)
        step_name = f"consensus_train[{ccfg.mode}]"
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        rt = PlainRuntime(model, mesh)
        batch = input_specs(cfg, shape)
        if shape.kind == "train":
            lowered = rt.lower_train(batch)
            step_name = "train"
        elif shape.kind == "prefill":
            lowered = rt.lower_prefill(batch)
            step_name = "prefill"
        else:
            lowered = rt.lower_decode(batch["cache"], batch["token"])
            step_name = "decode"

    n_chips = int(np.prod(mesh.devices.shape))
    compiled = lowered.compile()
    t_compile = time.time() - t0

    xla_cost = compiled.cost_analysis() or {}
    if isinstance(xla_cost, (list, tuple)):  # older jax: one dict per device
        xla_cost = xla_cost[0] if xla_cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception:
        mem_d = {}
    cost = analyze_hlo(compiled.as_text())
    terms = roofline_terms(cost.flops, cost.bytes, cost.collective_bytes)

    # model-level "useful" FLOPs: 6 N_active D tokens (training fwd+bwd) /
    # 2 N_active D (serve fwd) per token.
    n_params = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_params * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_params * tokens
    else:
        tokens = shape.global_batch  # one new token per sequence
        model_flops = 2.0 * n_params * tokens

    flops_global = cost.flops * n_chips
    rec = {
        "arch": arch,
        "shape": shape_name,
        "step": step_name,
        "opts": opts,
        "multi_pod": multi_pod,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "n_chips": n_chips,
        "compile_s": round(t_compile, 1),
        # per-device, trip-count-aware (roofline inputs)
        "flops_dev": cost.flops,
        "hbm_bytes_dev": cost.bytes,
        "collective_bytes_dev": cost.collective_bytes,
        "per_collective_dev": cost.per_collective,
        "collective_counts": cost.collective_counts,
        "unknown_trip_whiles": cost.unknown_trip_whiles,
        # XLA's own (loop-bodies-once) numbers, for reference
        "xla_flops_dev": float(xla_cost.get("flops", 0.0)),
        "xla_bytes_dev": float(xla_cost.get("bytes accessed", 0.0)),
        "memory": mem_d,
        "model_flops": model_flops,
        "useful_flop_frac": model_flops / flops_global if flops_global else None,
        **terms,
    }
    if verbose:
        print(json.dumps(rec, indent=None, default=str), flush=True)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="both")
    ap.add_argument("--consensus", action="store_true",
                    help="lower the csI-ADMM consensus train step instead")
    ap.add_argument("--opts", default="",
                    help='config overrides, e.g. "remat=full,attn_block_kv=2048"')
    ap.add_argument("--consensus-mode", default="incremental",
                    choices=("incremental", "parallel"))
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    records, skips, failures = [], [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'multi' if mp else 'single'}"
                try:
                    rec = run_one(arch, shape, mp, consensus=args.consensus,
                                  opts=args.opts,
                                  consensus_mode=args.consensus_mode)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((tag, repr(e)))
                    continue
                if rec is None:
                    continue
                if rec.get("skipped"):
                    skips.append(rec)
                    print(f"SKIP {tag}: {rec['skipped']}")
                else:
                    records.append(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec, default=str) + "\n")

    print(f"\n== dry-run complete: {len(records)} lowered, "
          f"{len(skips)} skipped, {len(failures)} failures ==")
    for tag, err in failures:
        print(f"FAIL {tag}: {err}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
