"""Data pipelines: least-squares datasets (paper §V) + LM token streams."""

from .lm import TokenStream, agent_token_streams, make_lm_batch
from .lsq import ecn_batch_indices, partition_for_code

__all__ = [
    "TokenStream",
    "agent_token_streams",
    "make_lm_batch",
    "ecn_batch_indices",
    "partition_for_code",
]
