"""Token pipeline for LM training examples.

Offline container => synthetic corpora: a deterministic mixture of (a) an
order-k Markov chain over the vocabulary (so the model has actual structure
to learn; loss decreases measurably within a few hundred steps) and (b)
uniform noise tokens. Each agent gets a *disjoint* stream (its own seed and
transition matrix sub-block) matching the paper's disjoint-allocation
assumption; ECN sub-batches slice the agent batch exactly like the
least-squares path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

__all__ = ["TokenStream", "agent_token_streams", "make_lm_batch"]


@dataclasses.dataclass
class TokenStream:
    """Deterministic synthetic token stream (Markov + noise mixture)."""

    vocab: int
    seed: int
    branching: int = 4  # successors per state
    noise: float = 0.05

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse deterministic-ish transition structure
        self._succ = rng.integers(
            0, self.vocab, size=(self.vocab, self.branching)
        )
        self._rng = np.random.default_rng(self.seed + 1)
        self._state = int(self._rng.integers(0, self.vocab))

    def sample(self, n: int) -> np.ndarray:
        out = np.empty(n, dtype=np.int32)
        s = self._state
        succ, rng, V = self._succ, self._rng, self.vocab
        noise_mask = rng.random(n) < self.noise
        choices = rng.integers(0, self.branching, size=n)
        noise_tok = rng.integers(0, V, size=n)
        for t in range(n):
            if noise_mask[t]:
                s = int(noise_tok[t])
            else:
                s = int(succ[s, choices[t]])
            out[t] = s
        self._state = s
        return out


def agent_token_streams(
    n_agents: int, vocab: int, seed: int = 0
) -> List[TokenStream]:
    """One disjoint stream per agent (own seed => own transition matrix)."""
    return [
        TokenStream(vocab=vocab, seed=seed * 1000 + i) for i in range(n_agents)
    ]


def make_lm_batch(
    stream: TokenStream, batch: int, seq_len: int
) -> Dict[str, np.ndarray]:
    """Next-token-prediction batch: labels are tokens shifted left."""
    raw = stream.sample(batch * (seq_len + 1)).reshape(batch, seq_len + 1)
    return {
        "tokens": raw[:, :-1].astype(np.int32),
        "labels": raw[:, 1:].astype(np.int32),
    }
