"""Coded data allocation for the least-squares experiments (Algorithms 1-2).

The partition/batch-index plumbing shared by `repro.core.admm` (faithful
simulator) and `repro.distributed` (mesh runtime):

- ``partition_for_code``: allocate an agent's local dataset across K ECNs
  following the code's row support (ECN j stores the partitions its encode
  row touches; disjoint for the uncoded identity code, (S+1)-replicated for
  fractional/cyclic repetition).
- ``ecn_batch_indices``: the paper's cyclic batch index
  I_{i,j}^k = m mod floor(|xi_{i,j}| * K / ((S+1) M_bar)) as absolute row
  offsets, so ECN j's mini-batch for cycle m is a static-size slice.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.coding import GradientCode

__all__ = ["partition_for_code", "ecn_batch_indices"]


def partition_for_code(
    b: int, code: GradientCode
) -> Tuple[np.ndarray, List[np.ndarray]]:
    """Split local row range [0, b) into K partitions + per-ECN supports.

    Returns (boundaries (K+1,), supports[j] = partition ids ECN j stores).
    Partition t owns rows [boundaries[t], boundaries[t+1]). Rows past
    b - b % K are dropped (static shapes).
    """
    K = code.K
    P = b // K
    if P == 0:
        raise ValueError(f"b={b} too small for K={K} partitions")
    boundaries = np.arange(K + 1) * P
    supports = [code.support(j) for j in range(K)]
    return boundaries, supports


def ecn_batch_indices(
    cycle: np.ndarray, P: int, mu: int
) -> np.ndarray:
    """Within-partition batch offsets for cycle indices m (paper step 15/16).

    Each partition of size P is cut into floor(P / mu) batches of size mu;
    cycle m selects batch m mod n_batches. Returns absolute offsets (len(m),).
    """
    nb = max(P // mu, 1)
    return ((np.asarray(cycle) % nb) * mu).astype(np.int32)
