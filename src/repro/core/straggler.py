"""Back-compat shim: the straggler model grew into `repro.core.timing`.

The paper-era `StragglerModel` (ECN response times with planted
stragglers, §V-A) is now the unified `TimingModel` that clocks EVERY
method kernel — gossip rounds and walk steps included — plus the
heterogeneous-fleet knobs (DESIGN.md §10) and the event-driven mode
(DESIGN.md §13). Import from `repro.core.timing` in new code; this
module keeps the original names importable but warns on import
(migration notes in DESIGN.md §13).
"""

from __future__ import annotations

import warnings

from .timing import StragglerModel, TimingModel, sample_times

warnings.warn(
    "repro.core.straggler is deprecated: import StragglerModel/"
    "TimingModel/sample_times from repro.core.timing instead "
    "(DESIGN.md §13)",
    DeprecationWarning,
    stacklevel=2,
)

__all__ = ["StragglerModel", "TimingModel", "sample_times"]
