"""Straggler / timing models for ECN edge computing — paper §V-A.

The paper measures "running time" = communication time among agents (per-link
uniform U(1e-5, 1e-4) s) + per-iteration response time of the edge compute
(decided by the slowest needed ECN), with a maximum straggler delay cap
``epsilon``. csI-ADMM's response time is the R-th fastest ECN; uncoded
sI-ADMM waits for all K (capped at epsilon, dropping late responses).

We reproduce that timing model exactly; all times are *simulated* (the
container has no cluster — the paper itself simulates delays on a laptop).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["StragglerModel", "sample_times"]


@dataclasses.dataclass(frozen=True)
class StragglerModel:
    """Per-ECN response-time distribution with planted stragglers.

    Every ECN draws a base compute time ~ U(base_lo, base_hi). In each
    iteration, each ECN independently straggles with probability
    ``p_straggle``; stragglers add a delay ~ Exp(mean=delay). ``epsilon``
    caps how long an agent will wait (paper's maximum delay parameter).
    """

    base_lo: float = 1e-4
    base_hi: float = 2e-4
    p_straggle: float = 0.1
    delay: float = 5e-3
    epsilon: float = 1e-2
    comm_lo: float = 1e-5  # per-link agent<->agent token time (paper §V-A)
    comm_hi: float = 1e-4

    def sample_ecn_times(
        self, iters: int, K: int, rng: np.random.Generator
    ) -> np.ndarray:
        """(iters, K) response times (uncapped; caller applies epsilon)."""
        base = rng.uniform(self.base_lo, self.base_hi, size=(iters, K))
        straggle = rng.random((iters, K)) < self.p_straggle
        extra = rng.exponential(self.delay, size=(iters, K))
        return base + straggle * extra

    def sample_link_times(
        self, iters: int, rng: np.random.Generator
    ) -> np.ndarray:
        """(iters,) per-hop token communication times."""
        return rng.uniform(self.comm_lo, self.comm_hi, size=iters)


def sample_times(
    model: StragglerModel, iters: int, K: int, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    return model.sample_ecn_times(iters, K, rng), model.sample_link_times(
        iters, rng
    )
