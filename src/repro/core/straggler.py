"""Back-compat shim: the straggler model grew into `repro.core.timing`.

The paper-era `StragglerModel` (ECN response times with planted
stragglers, §V-A) is now the unified `TimingModel` that clocks EVERY
method kernel — gossip rounds and walk steps included — plus the
heterogeneous-fleet knobs (DESIGN.md §10). Import from
`repro.core.timing` in new code; this module keeps the original names
importable.
"""

from __future__ import annotations

from .timing import StragglerModel, TimingModel, sample_times

__all__ = ["StragglerModel", "TimingModel", "sample_times"]
