"""State-of-the-art baselines the paper compares against (§V-A).

  1) W-ADMM  [3]  — random-walk incremental ADMM (Walkman): same incremental
                    updates as sI-ADMM but the token performs a uniform random
                    walk over neighbors (one agent + one link per iteration).
  2) D-ADMM  [14]/[9] — gossip-style decentralized consensus ADMM: every agent
                    updates every iteration using all its neighbors (2|E|
                    directed messages per iteration).
  3) DGD     [6]  — decentralized gradient descent with Metropolis mixing and
                    diminishing step size.
  4) EXTRA   [7]  — exact first-order gossip method with constant step size.

All baselines run on the same `LeastSquaresProblem` and report the same
metrics as `repro.core.admm` (accuracy eq. 23, test error, cumulative
communication units) so the benchmark figures are directly comparable.
Gossip baselines use full local gradients (as in the original methods);
incremental baselines use the same stochastic oracle as sI-ADMM.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .admm import ADMMConfig, Trace
from .graph import Network, metropolis_weights
from .problems import LeastSquaresProblem

__all__ = [
    "run_wadmm",
    "run_dadmm",
    "run_dgd",
    "run_extra",
    "run_wadmm_batch",
    "run_dadmm_batch",
    "run_dgd_batch",
    "run_extra_batch",
]


def _batched(impl, static_names):
    """jit(vmap(impl)) with the given keyword statics (DESIGN.md §7)."""

    @partial(jax.jit, static_argnames=static_names)
    def batched(*arrays, **statics):
        return jax.vmap(partial(impl, **statics))(*arrays)

    return batched


def _stack(runs: Sequence[tuple]):
    return tuple(
        jnp.asarray(np.stack([np.asarray(r[i]) for r in runs]))
        for i in range(len(runs[0]))
    )


def _metrics(x, z_mean, x_star, xs_norm, O_test, T_test, N):
    acc = jnp.mean(
        jnp.linalg.norm((x - x_star[None]).reshape(N, -1), axis=1)
        / jnp.maximum(xs_norm, 1e-12)
    )
    r = O_test @ z_mean - T_test
    test_err = jnp.mean(jnp.sum(r * r, axis=-1))
    z_err = jnp.linalg.norm(z_mean - x_star) / jnp.maximum(xs_norm, 1e-12)
    return acc, test_err, z_err


def _trace(acc, test_err, z_err, comm_per_iter, x, z) -> Trace:
    iters = len(np.asarray(acc))
    comm = np.cumsum(np.full(iters, float(comm_per_iter)))
    return Trace(
        accuracy=np.asarray(acc),
        test_error=np.asarray(test_err),
        comm_cost=comm,
        sim_time=np.zeros(iters),
        z_err=np.asarray(z_err),
        final_x=np.asarray(x),
        final_z=np.asarray(z),
    )


# --------------------------------------------------------------------------
# W-ADMM (Walkman) — random-walk incremental ADMM
# --------------------------------------------------------------------------


def _walk_arrays(problem: LeastSquaresProblem, net: Network, cfg: ADMMConfig, iters: int):
    N, b = problem.N, problem.b
    rng = np.random.default_rng(cfg.seed)
    # Random walk over neighbors.
    agents = np.zeros(iters, dtype=np.int32)
    cur = int(rng.integers(N))
    for k in range(iters):
        agents[k] = cur
        cur = int(rng.choice(net.neighbors(cur)))
    M = cfg.M
    nb = max(b // M, 1)
    offsets = ((np.arange(iters) // N % nb) * M).astype(np.int32)
    tau = cfg.c_tau * np.sqrt(np.arange(1, iters + 1))
    gamma = cfg.c_gamma / np.sqrt(np.arange(1, iters + 1))
    dt = problem.O.dtype
    return (
        problem.O,
        problem.T,
        problem.x_star().astype(dt),
        problem.O_test,
        problem.T_test,
        agents,
        offsets,
        tau.astype(dt),
        gamma.astype(dt),
        np.asarray(cfg.rho, dtype=dt),
    )


def run_wadmm(
    problem: LeastSquaresProblem,
    net: Network,
    cfg: ADMMConfig,
    iters: int,
) -> Trace:
    """Walkman with the same stochastic proximal-linearized x-update."""
    arrays = _walk_arrays(problem, net, cfg, iters)
    x, z, acc, test_err, z_err = _scan_walk(
        *(jnp.asarray(a) for a in arrays), M=cfg.M, N=problem.N
    )
    return _trace(acc, test_err, z_err, 1.0, x, z)


def run_wadmm_batch(
    problems: Sequence[LeastSquaresProblem],
    nets: Sequence[Network],
    cfgs: Sequence[ADMMConfig],
    iters: int,
) -> List[Trace]:
    """All runs as one vmapped scan; requires uniform (M, N, shapes)."""
    sigs = {(c.M, p.N, p.O.shape, p.T.shape) for p, c in zip(problems, cfgs)}
    if len(sigs) != 1:
        raise ValueError(f"batch mixes static signatures: {sigs}")
    runs = [
        _walk_arrays(p, n, c, iters)
        for p, n, c in zip(problems, nets, cfgs)
    ]
    out = _scan_walk_batched(*_stack(runs), M=cfgs[0].M, N=problems[0].N)
    out = [np.asarray(o) for o in out]
    return [
        _trace(*(o[r] for o in out[2:]), 1.0, out[0][r], out[1][r])
        for r in range(len(runs))
    ]


def _scan_walk_impl(O, T, x_star, O_test, T_test, agents, offsets, tau, gamma, rho, *, M, N):
    p, d = O.shape[2], T.shape[2]
    x0 = jnp.zeros((N, p, d), O.dtype)
    y0 = jnp.zeros((N, p, d), O.dtype)
    z0 = jnp.zeros((p, d), O.dtype)
    xs_norm = jnp.linalg.norm(x_star)

    def step(carry, inp):
        x, y, z = carry
        i, off, tk, gk = inp
        zero = jnp.zeros((), off.dtype)
        Ob = jax.lax.dynamic_slice(O[i], (off, zero), (M, p))
        Tb = jax.lax.dynamic_slice(T[i], (off, zero), (M, d))
        xi, yi = x[i], y[i]
        G = Ob.T @ (Ob @ xi - Tb) / M
        x_new = (tk * xi + rho * z + yi - G) / (rho + tk)
        y_new = yi + rho * gk * (z - x_new)
        z_new = z + ((x_new - xi) - (y_new - yi) / rho) / N
        x = x.at[i].set(x_new)
        y = y.at[i].set(y_new)
        return (x, y, z_new), _metrics(
            x, z_new, x_star, xs_norm, O_test, T_test, N
        )

    (x, y, z), out = jax.lax.scan(
        step, (x0, y0, z0), (agents, offsets, tau, gamma)
    )
    return x, z, *out


_scan_walk = partial(jax.jit, static_argnames=("M", "N"))(_scan_walk_impl)
_scan_walk_batched = _batched(_scan_walk_impl, ("M", "N"))


# --------------------------------------------------------------------------
# D-ADMM — gossip decentralized consensus ADMM
# --------------------------------------------------------------------------


def _dadmm_arrays(problem: LeastSquaresProblem, net: Network, rho: float):
    dt = problem.O.dtype
    return (
        problem.O,
        problem.T,
        net.adjacency.astype(dt),
        net.degree().astype(dt),
        problem.x_star().astype(dt),
        problem.O_test,
        problem.T_test,
        np.asarray(rho, dtype=dt),
    )


def run_dadmm(
    problem: LeastSquaresProblem,
    net: Network,
    rho: float,
    iters: int,
) -> Trace:
    arrays = _dadmm_arrays(problem, net, rho)
    x, acc, test_err, z_err = _scan_dadmm(
        *(jnp.asarray(a) for a in arrays), iters=iters
    )
    return _trace(acc, test_err, z_err, 2 * net.E, x, np.asarray(x).mean(0))


def run_dadmm_batch(
    problems: Sequence[LeastSquaresProblem],
    nets: Sequence[Network],
    rhos: Sequence[float],
    iters: int,
) -> List[Trace]:
    runs = [
        _dadmm_arrays(p, n, r) for p, n, r in zip(problems, nets, rhos)
    ]
    out = _scan_dadmm_batched(*_stack(runs), iters=iters)
    x, acc, test_err, z_err = (np.asarray(o) for o in out)
    return [
        _trace(acc[r], test_err[r], z_err[r], 2 * nets[r].E, x[r], x[r].mean(0))
        for r in range(len(runs))
    ]


def _scan_dadmm_impl(O, T, A, deg, x_star, O_test, T_test, rho, *, iters):
    N, b, p = O.shape
    d = T.shape[2]
    xs_norm = jnp.linalg.norm(x_star)
    H = jnp.einsum("nbp,nbq->npq", O, O) / b  # (N, p, p)
    rhs0 = jnp.einsum("nbp,nbd->npd", O, T) / b
    eye = jnp.eye(p, dtype=O.dtype)
    # Per-agent solve operator: (H_i + 2 rho d_i I)
    Hs = H + 2.0 * rho * deg[:, None, None] * eye[None]

    def step(carry, _):
        x, alpha = carry
        nbr_sum = jnp.einsum("ij,jpd->ipd", A, x)
        rhs = rhs0 + rho * (deg[:, None, None] * x + nbr_sum) - alpha
        x_new = jnp.linalg.solve(Hs, rhs)
        nbr_sum_new = jnp.einsum("ij,jpd->ipd", A, x_new)
        alpha = alpha + rho * (deg[:, None, None] * x_new - nbr_sum_new)
        z_mean = x_new.mean(0)
        return (x_new, alpha), _metrics(
            x_new, z_mean, x_star, xs_norm, O_test, T_test, N
        )

    x0 = jnp.zeros((N, p, d), O.dtype)
    (x, _), out = jax.lax.scan(step, (x0, x0), None, length=iters)
    return x, *out


_scan_dadmm = partial(jax.jit, static_argnames=("iters",))(_scan_dadmm_impl)
_scan_dadmm_batched = _batched(_scan_dadmm_impl, ("iters",))


# --------------------------------------------------------------------------
# DGD and EXTRA — gossip first-order methods
# --------------------------------------------------------------------------


def _dgd_arrays(
    problem: LeastSquaresProblem, net: Network, alpha0: float, iters: int,
    diminishing: bool,
):
    dt = problem.O.dtype
    steps = (
        alpha0 / np.sqrt(np.arange(1, iters + 1))
        if diminishing
        else np.full(iters, alpha0)
    )
    return (
        problem.O,
        problem.T,
        metropolis_weights(net).astype(dt),
        problem.x_star().astype(dt),
        problem.O_test,
        problem.T_test,
        steps.astype(dt),
    )


def run_dgd(
    problem: LeastSquaresProblem,
    net: Network,
    alpha0: float,
    iters: int,
    diminishing: bool = True,
) -> Trace:
    arrays = _dgd_arrays(problem, net, alpha0, iters, diminishing)
    x, acc, test_err, z_err = _scan_dgd(*(jnp.asarray(a) for a in arrays))
    return _trace(acc, test_err, z_err, 2 * net.E, x, np.asarray(x).mean(0))


def run_dgd_batch(
    problems: Sequence[LeastSquaresProblem],
    nets: Sequence[Network],
    alpha0s: Sequence[float],
    iters: int,
    diminishing: bool = True,
) -> List[Trace]:
    runs = [
        _dgd_arrays(p, n, a, iters, diminishing)
        for p, n, a in zip(problems, nets, alpha0s)
    ]
    out = _scan_dgd_batched(*_stack(runs))
    x, acc, test_err, z_err = (np.asarray(o) for o in out)
    return [
        _trace(acc[r], test_err[r], z_err[r], 2 * nets[r].E, x[r], x[r].mean(0))
        for r in range(len(runs))
    ]


def _scan_dgd_impl(O, T, W, x_star, O_test, T_test, steps):
    N, b, p = O.shape
    d = T.shape[2]
    xs_norm = jnp.linalg.norm(x_star)

    def grad(x):
        return jnp.einsum("nbp,nbd->npd", O, jnp.einsum("nbp,npd->nbd", O, x) - T) / b

    def step(x, alpha):
        x_new = jnp.einsum("ij,jpd->ipd", W, x) - alpha * grad(x)
        return x_new, _metrics(
            x_new, x_new.mean(0), x_star, xs_norm, O_test, T_test, N
        )

    x0 = jnp.zeros((N, p, d), O.dtype)
    x, out = jax.lax.scan(step, x0, steps)
    return x, *out


_scan_dgd = jax.jit(_scan_dgd_impl)
_scan_dgd_batched = _batched(_scan_dgd_impl, ())


def _extra_arrays(problem: LeastSquaresProblem, net: Network, alpha: float):
    dt = problem.O.dtype
    return (
        problem.O,
        problem.T,
        metropolis_weights(net).astype(dt),
        problem.x_star().astype(dt),
        problem.O_test,
        problem.T_test,
        np.asarray(alpha, dtype=dt),
    )


def run_extra(
    problem: LeastSquaresProblem,
    net: Network,
    alpha: float,
    iters: int,
) -> Trace:
    arrays = _extra_arrays(problem, net, alpha)
    x, acc, test_err, z_err = _scan_extra(
        *(jnp.asarray(a) for a in arrays), iters=iters
    )
    return _trace(acc, test_err, z_err, 2 * net.E, x, np.asarray(x).mean(0))


def run_extra_batch(
    problems: Sequence[LeastSquaresProblem],
    nets: Sequence[Network],
    alphas: Sequence[float],
    iters: int,
) -> List[Trace]:
    runs = [
        _extra_arrays(p, n, a) for p, n, a in zip(problems, nets, alphas)
    ]
    out = _scan_extra_batched(*_stack(runs), iters=iters)
    x, acc, test_err, z_err = (np.asarray(o) for o in out)
    return [
        _trace(acc[r], test_err[r], z_err[r], 2 * nets[r].E, x[r], x[r].mean(0))
        for r in range(len(runs))
    ]


def _scan_extra_impl(O, T, W, x_star, O_test, T_test, alpha, *, iters):
    N, b, p = O.shape
    d = T.shape[2]
    xs_norm = jnp.linalg.norm(x_star)
    W_tilde = 0.5 * (jnp.eye(N, dtype=O.dtype) + W)

    def grad(x):
        return jnp.einsum("nbp,nbd->npd", O, jnp.einsum("nbp,npd->nbd", O, x) - T) / b

    x0 = jnp.zeros((N, p, d), O.dtype)
    x1 = jnp.einsum("ij,jpd->ipd", W, x0) - alpha * grad(x0)

    def step(carry, _):
        x_prev, x_cur = carry
        x_next = (
            jnp.einsum("ij,jpd->ipd", jnp.eye(N, dtype=O.dtype) + W, x_cur)
            - jnp.einsum("ij,jpd->ipd", W_tilde, x_prev)
            - alpha * (grad(x_cur) - grad(x_prev))
        )
        return (x_cur, x_next), _metrics(
            x_next, x_next.mean(0), x_star, xs_norm, O_test, T_test, N
        )

    (_, x), out = jax.lax.scan(step, (x0, x1), None, length=iters)
    return x, *out


_scan_extra = partial(jax.jit, static_argnames=("iters",))(_scan_extra_impl)
_scan_extra_batched = _batched(_scan_extra_impl, ("iters",))
