"""State-of-the-art baselines the paper compares against (§V-A).

  1) W-ADMM  [3]  — random-walk incremental ADMM (Walkman): same incremental
                    updates as sI-ADMM but the token performs a uniform random
                    walk over neighbors (one agent + one link per iteration).
  2) D-ADMM  [14]/[9] — gossip-style decentralized consensus ADMM: every agent
                    updates every iteration using all its neighbors (2|E|
                    directed messages per iteration).
  3) DGD     [6]  — decentralized gradient descent with Metropolis mixing and
                    diminishing step size.
  4) EXTRA   [7]  — exact first-order gossip method with constant step size.

All baselines run on the same `LeastSquaresProblem` and report the same
metrics as `repro.core.admm` (accuracy eq. 23, test error, cumulative
communication units) so the benchmark figures are directly comparable.
Gossip baselines use full local gradients (as in the original methods);
incremental baselines use the same stochastic oracle as sI-ADMM.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .admm import ADMMConfig, Trace
from .graph import Network, metropolis_weights
from .problems import LeastSquaresProblem

__all__ = ["run_wadmm", "run_dadmm", "run_dgd", "run_extra"]


def _metrics(x, z_mean, x_star, xs_norm, O_test, T_test, N):
    acc = jnp.mean(
        jnp.linalg.norm((x - x_star[None]).reshape(N, -1), axis=1)
        / jnp.maximum(xs_norm, 1e-12)
    )
    r = O_test @ z_mean - T_test
    test_err = jnp.mean(jnp.sum(r * r, axis=-1))
    z_err = jnp.linalg.norm(z_mean - x_star) / jnp.maximum(xs_norm, 1e-12)
    return acc, test_err, z_err


def _trace(acc, test_err, z_err, comm_per_iter, x, z) -> Trace:
    iters = len(np.asarray(acc))
    comm = np.cumsum(np.full(iters, float(comm_per_iter)))
    return Trace(
        accuracy=np.asarray(acc),
        test_error=np.asarray(test_err),
        comm_cost=comm,
        sim_time=np.zeros(iters),
        z_err=np.asarray(z_err),
        final_x=np.asarray(x),
        final_z=np.asarray(z),
    )


# --------------------------------------------------------------------------
# W-ADMM (Walkman) — random-walk incremental ADMM
# --------------------------------------------------------------------------


def run_wadmm(
    problem: LeastSquaresProblem,
    net: Network,
    cfg: ADMMConfig,
    iters: int,
) -> Trace:
    """Walkman with the same stochastic proximal-linearized x-update."""
    N, p, d, b = problem.N, problem.p, problem.d, problem.b
    rng = np.random.default_rng(cfg.seed)
    # Random walk over neighbors.
    agents = np.zeros(iters, dtype=np.int32)
    cur = int(rng.integers(N))
    for k in range(iters):
        agents[k] = cur
        cur = int(rng.choice(net.neighbors(cur)))
    M = cfg.M
    nb = max(b // M, 1)
    offsets = ((np.arange(iters) // N % nb) * M).astype(np.int32)
    tau = cfg.c_tau * np.sqrt(np.arange(1, iters + 1))
    gamma = cfg.c_gamma / np.sqrt(np.arange(1, iters + 1))

    x_star = problem.x_star()
    x, z, acc, test_err, z_err = _scan_walk(
        jnp.asarray(problem.O),
        jnp.asarray(problem.T),
        jnp.asarray(x_star.astype(problem.O.dtype)),
        jnp.asarray(problem.O_test),
        jnp.asarray(problem.T_test),
        jnp.asarray(agents),
        jnp.asarray(offsets),
        jnp.asarray(tau.astype(problem.O.dtype)),
        jnp.asarray(gamma.astype(problem.O.dtype)),
        float(cfg.rho),
        M=M,
        N=N,
    )
    return _trace(acc, test_err, z_err, 1.0, x, z)


@partial(jax.jit, static_argnames=("M", "N"))
def _scan_walk(O, T, x_star, O_test, T_test, agents, offsets, tau, gamma, rho, *, M, N):
    p, d = O.shape[2], T.shape[2]
    x0 = jnp.zeros((N, p, d), O.dtype)
    y0 = jnp.zeros((N, p, d), O.dtype)
    z0 = jnp.zeros((p, d), O.dtype)
    xs_norm = jnp.linalg.norm(x_star)

    def step(carry, inp):
        x, y, z = carry
        i, off, tk, gk = inp
        zero = jnp.zeros((), off.dtype)
        Ob = jax.lax.dynamic_slice(O[i], (off, zero), (M, p))
        Tb = jax.lax.dynamic_slice(T[i], (off, zero), (M, d))
        xi, yi = x[i], y[i]
        G = Ob.T @ (Ob @ xi - Tb) / M
        x_new = (tk * xi + rho * z + yi - G) / (rho + tk)
        y_new = yi + rho * gk * (z - x_new)
        z_new = z + ((x_new - xi) - (y_new - yi) / rho) / N
        x = x.at[i].set(x_new)
        y = y.at[i].set(y_new)
        return (x, y, z_new), _metrics(
            x, z_new, x_star, xs_norm, O_test, T_test, N
        )

    (x, y, z), out = jax.lax.scan(
        step, (x0, y0, z0), (agents, offsets, tau, gamma)
    )
    return x, z, *out


# --------------------------------------------------------------------------
# D-ADMM — gossip decentralized consensus ADMM
# --------------------------------------------------------------------------


def run_dadmm(
    problem: LeastSquaresProblem,
    net: Network,
    rho: float,
    iters: int,
) -> Trace:
    N, p = problem.N, problem.p
    A = jnp.asarray(net.adjacency.astype(problem.O.dtype))
    deg = jnp.asarray(net.degree().astype(problem.O.dtype))
    x_star = problem.x_star()
    x, acc, test_err, z_err = _scan_dadmm(
        jnp.asarray(problem.O),
        jnp.asarray(problem.T),
        A,
        deg,
        jnp.asarray(x_star.astype(problem.O.dtype)),
        jnp.asarray(problem.O_test),
        jnp.asarray(problem.T_test),
        float(rho),
        iters=iters,
    )
    return _trace(acc, test_err, z_err, 2 * net.E, x, np.asarray(x).mean(0))


@partial(jax.jit, static_argnames=("iters",))
def _scan_dadmm(O, T, A, deg, x_star, O_test, T_test, rho, *, iters):
    N, b, p = O.shape
    d = T.shape[2]
    xs_norm = jnp.linalg.norm(x_star)
    H = jnp.einsum("nbp,nbq->npq", O, O) / b  # (N, p, p)
    rhs0 = jnp.einsum("nbp,nbd->npd", O, T) / b
    eye = jnp.eye(p, dtype=O.dtype)
    # Per-agent solve operator: (H_i + 2 rho d_i I)
    Hs = H + 2.0 * rho * deg[:, None, None] * eye[None]

    def step(carry, _):
        x, alpha = carry
        nbr_sum = jnp.einsum("ij,jpd->ipd", A, x)
        rhs = rhs0 + rho * (deg[:, None, None] * x + nbr_sum) - alpha
        x_new = jnp.linalg.solve(Hs, rhs)
        nbr_sum_new = jnp.einsum("ij,jpd->ipd", A, x_new)
        alpha = alpha + rho * (deg[:, None, None] * x_new - nbr_sum_new)
        z_mean = x_new.mean(0)
        return (x_new, alpha), _metrics(
            x_new, z_mean, x_star, xs_norm, O_test, T_test, N
        )

    x0 = jnp.zeros((N, p, d), O.dtype)
    (x, _), out = jax.lax.scan(step, (x0, x0), None, length=iters)
    return x, *out


# --------------------------------------------------------------------------
# DGD and EXTRA — gossip first-order methods
# --------------------------------------------------------------------------


def run_dgd(
    problem: LeastSquaresProblem,
    net: Network,
    alpha0: float,
    iters: int,
    diminishing: bool = True,
) -> Trace:
    W = jnp.asarray(metropolis_weights(net).astype(problem.O.dtype))
    x_star = problem.x_star()
    steps = alpha0 / np.sqrt(np.arange(1, iters + 1)) if diminishing else np.full(iters, alpha0)
    x, acc, test_err, z_err = _scan_dgd(
        jnp.asarray(problem.O),
        jnp.asarray(problem.T),
        W,
        jnp.asarray(x_star.astype(problem.O.dtype)),
        jnp.asarray(problem.O_test),
        jnp.asarray(problem.T_test),
        jnp.asarray(steps.astype(problem.O.dtype)),
    )
    return _trace(acc, test_err, z_err, 2 * net.E, x, np.asarray(x).mean(0))


@jax.jit
def _scan_dgd(O, T, W, x_star, O_test, T_test, steps):
    N, b, p = O.shape
    d = T.shape[2]
    xs_norm = jnp.linalg.norm(x_star)

    def grad(x):
        return jnp.einsum("nbp,nbd->npd", O, jnp.einsum("nbp,npd->nbd", O, x) - T) / b

    def step(x, alpha):
        x_new = jnp.einsum("ij,jpd->ipd", W, x) - alpha * grad(x)
        return x_new, _metrics(
            x_new, x_new.mean(0), x_star, xs_norm, O_test, T_test, N
        )

    x0 = jnp.zeros((N, p, d), O.dtype)
    x, out = jax.lax.scan(step, x0, steps)
    return x, *out


def run_extra(
    problem: LeastSquaresProblem,
    net: Network,
    alpha: float,
    iters: int,
) -> Trace:
    W = jnp.asarray(metropolis_weights(net).astype(problem.O.dtype))
    x_star = problem.x_star()
    x, acc, test_err, z_err = _scan_extra(
        jnp.asarray(problem.O),
        jnp.asarray(problem.T),
        W,
        jnp.asarray(x_star.astype(problem.O.dtype)),
        jnp.asarray(problem.O_test),
        jnp.asarray(problem.T_test),
        float(alpha),
        iters=iters,
    )
    return _trace(acc, test_err, z_err, 2 * net.E, x, np.asarray(x).mean(0))


@partial(jax.jit, static_argnames=("iters",))
def _scan_extra(O, T, W, x_star, O_test, T_test, alpha, *, iters):
    N, b, p = O.shape
    d = T.shape[2]
    xs_norm = jnp.linalg.norm(x_star)
    W_tilde = 0.5 * (jnp.eye(N, dtype=O.dtype) + W)

    def grad(x):
        return jnp.einsum("nbp,nbd->npd", O, jnp.einsum("nbp,npd->nbd", O, x) - T) / b

    x0 = jnp.zeros((N, p, d), O.dtype)
    x1 = jnp.einsum("ij,jpd->ipd", W, x0) - alpha * grad(x0)

    def step(carry, _):
        x_prev, x_cur = carry
        x_next = (
            jnp.einsum("ij,jpd->ipd", jnp.eye(N, dtype=O.dtype) + W, x_cur)
            - jnp.einsum("ij,jpd->ipd", W_tilde, x_prev)
            - alpha * (grad(x_cur) - grad(x_prev))
        )
        return (x_cur, x_next), _metrics(
            x_next, x_next.mean(0), x_star, xs_norm, O_test, T_test, N
        )

    (_, x), out = jax.lax.scan(step, (x0, x1), None, length=iters)
    return x, *out
