"""State-of-the-art baselines the paper compares against (§V-A).

  1) W-ADMM  [3]  — random-walk incremental ADMM (Walkman): same incremental
                    updates as sI-ADMM but the token performs a uniform random
                    walk over neighbors (one agent + one link per iteration).
  2) D-ADMM  [14]/[9] — gossip-style decentralized consensus ADMM: every agent
                    updates every iteration using all its neighbors (2|E|
                    directed messages per iteration).
  3) DGD     [6]  — decentralized gradient descent with Metropolis mixing and
                    diminishing step size.
  4) EXTRA   [7]  — exact first-order gossip method with constant step size.

All baselines run on the same `LeastSquaresProblem` and report the same
metrics as `repro.core.admm` (accuracy eq. 23, test error, cumulative
communication units) so the benchmark figures are directly comparable.

These are thin serial entry points over the method kernels
(`repro.methods.walkman`, `repro.methods.gossip`) — each algorithm has
exactly ONE step implementation, and batched execution is the `vmap`
derivation of the same step (`repro.methods.driver`, DESIGN.md §8).
"""

from __future__ import annotations

from .admm import ADMMConfig, Trace
from .graph import Network
from .problems import LeastSquaresProblem

__all__ = [
    "run_wadmm",
    "run_dadmm",
    "run_dgd",
    "run_extra",
]


def run_wadmm(
    problem: LeastSquaresProblem,
    net: Network,
    cfg: ADMMConfig,
    iters: int,
) -> Trace:
    """Walkman with the same stochastic proximal-linearized x-update."""
    from repro.methods import ADMMRun, get_kernel, run_serial

    return run_serial(get_kernel("W-ADMM"), problem, net, ADMMRun(cfg), iters)


def run_dadmm(
    problem: LeastSquaresProblem,
    net: Network,
    rho: float,
    iters: int,
) -> Trace:
    from repro.methods import GossipRun, get_kernel, run_serial

    return run_serial(get_kernel("D-ADMM"), problem, net, GossipRun(rho), iters)


def run_dgd(
    problem: LeastSquaresProblem,
    net: Network,
    alpha0: float,
    iters: int,
    diminishing: bool = True,
) -> Trace:
    from repro.methods import GossipRun, get_kernel, run_serial

    return run_serial(
        get_kernel("DGD"), problem, net,
        GossipRun(alpha0, diminishing=diminishing), iters,
    )


def run_extra(
    problem: LeastSquaresProblem,
    net: Network,
    alpha: float,
    iters: int,
) -> Trace:
    from repro.methods import GossipRun, get_kernel, run_serial

    return run_serial(get_kernel("EXTRA"), problem, net, GossipRun(alpha), iters)
