"""(K, R) gradient coding over the real field — paper §III-B, as a
pluggable code-family subsystem (DESIGN.md §11).

A *family* is one construction recipe (feasibility rule + certified
builder); a built `GradientCode` is the runtime artifact every consumer
shares (the schedule sampler, the method kernels, the Pallas combine
path). Registered families:

- **fractional**: Tandon et al. [23] deterministic 0/1 encoding. The K
  ECNs split into (S+1) groups of K/(S+1); each group disjointly covers
  all K partitions, so any K-S alive ECNs contain an intact group
  (pigeonhole) whose indicator is the decode vector. Needs (S+1) | K.
- **cyclic**: Tandon et al.'s randomized construction. ECN j holds
  partitions {j, ..., j+S} (mod K); draw H in R^{S x K} with H @ 1 = 0
  and read row j of B off null(H) restricted to the support. rowspan(B)
  = null(H) contains the all-ones vector and any K-S rows span it
  (general position) — certified at construction, re-drawn on failure.
  The paper's Fig. 2 example (K=3, S=1) is this scheme:
      g1 = 1/2 g~1 + g~2 ,  g2 = g~2 - g~3 ,  g3 = 1/2 g~1 + g~3.
- **mds**: real-field MDS code. B = W @ V with W the (K, R) Vandermonde
  matrix on Chebyshev nodes (any R rows invertible) and V an (R, K)
  orthonormal basis whose rowspan contains 1_K, so ANY >= R responses
  decode exactly via least squares. Dense rows: replication = K (full
  storage/compute), the classic MDS storage-for-flexibility trade.
- **approx**: partial-recovery gradient code (the approximate gradient
  coding regime of Raviv et al. / the compressed-stochastic extensions
  of arXiv 2501.13516). Same B and storage as cyclic — exact from any
  R = K - S responses — but decode is *also* defined for as few as
  r_min = max(1, K - 2S) responses, with the worst-case least-squares
  residual over all r_min-size alive patterns certified at construction
  as ``err_bound``: for any alive set with >= r_min responses,
  |a^T B g - 1^T g| <= err_bound * ||g||_2 per gradient coordinate.
  This is what the decode *deadline* of `repro.core.timing.TimingModel`
  cashes in (DESIGN.md §11).
- **uncoded**: disjoint allocation (sI-ADMM, Algorithm 1): B = I, the
  agent must hear from every ECN (S = 0).

Encoding/decoding are linear maps over stacked partition gradients, so
the same matrices drive the faithful simulator (`repro.core.admm`) and
the fused Pallas combine (`repro.kernels.coded_combine`), where decode
becomes a masked weighted reduction over message rows.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional

import numpy as np

__all__ = [
    "GradientCode",
    "CodeFamily",
    "CODE_FAMILIES",
    "register_family",
    "make_code",
    "check_arm_set",
    "make_arm_set",
    "fractional_repetition_code",
    "cyclic_repetition_code",
    "mds_code",
    "approx_code",
    "uncoded",
    "paper_fig2_code",
]


@dataclasses.dataclass(frozen=True)
class GradientCode:
    """A certified (K, R) gradient code.

    Attributes:
      name: family name ("fractional", "cyclic", "mds", "approx",
        "uncoded").
      K: number of ECNs (= number of data partitions, d = n in [23]).
      S: number of tolerated stragglers; R = K - S responses decode
        exactly (for exact families).
      B: (K, K) encode matrix. ECN j transmits ``B[j] @ partial_grads``
        where ``partial_grads`` stacks the K per-partition gradients.
        Row support of B[j] is the set of partitions ECN j must
        store/compute.
      r_min: minimum responses ``decode_vector`` accepts; ``None`` means
        R (exact-only decode). Partial-recovery families set r_min < R.
      err_bound: certified worst-case decode residual
        max_{|alive| >= r_min} min_a ||B[alive]^T a - 1||_2 — zero for
        exact families. The decoded gradient sum errs by at most
        ``err_bound * ||g||_2`` per coordinate (Cauchy-Schwarz).
    """

    name: str
    K: int
    S: int
    B: np.ndarray  # (K, K) float64
    r_min: Optional[int] = None
    err_bound: float = 0.0

    @property
    def R(self) -> int:
        return self.K - self.S

    @property
    def min_responses(self) -> int:
        """Fewest responses decode accepts (R unless partial recovery)."""
        return self.R if self.r_min is None else self.r_min

    @property
    def exact(self) -> bool:
        """True iff every accepted alive pattern decodes exactly."""
        return self.err_bound == 0.0

    def support(self, j: int) -> np.ndarray:
        """Partition indices ECN j computes gradients for."""
        return np.nonzero(np.abs(self.B[j]) > 1e-12)[0]

    @property
    def replication(self) -> int:
        """Max #partitions per ECN (storage/compute overhead factor)."""
        return int(max(len(self.support(j)) for j in range(self.K)))

    def encode(self, partial_grads: np.ndarray) -> np.ndarray:
        """Coded messages from stacked per-partition gradients (K, ...)."""
        g = np.asarray(partial_grads)
        return np.tensordot(self.B, g.reshape(self.K, -1), axes=1).reshape(
            g.shape
        )

    def _decode_tol(self) -> float:
        return 1e-6 if self.exact else self.err_bound * (1 + 1e-6) + 1e-9

    def decode_vector(self, alive: np.ndarray) -> np.ndarray:
        """a with a^T B ~= 1^T and a supported on alive ECNs.

        ``alive`` is a boolean mask of length K with >= ``min_responses``
        True entries. Exact families require an exact solve (residual
        <= 1e-6); partial-recovery families accept any residual within
        the certified ``err_bound``. Raises ValueError otherwise.
        """
        alive = np.asarray(alive, dtype=bool)
        if alive.sum() < self.min_responses:
            raise ValueError(
                f"need >= r_min={self.min_responses} responses, "
                f"got {int(alive.sum())}"
            )
        idx = np.nonzero(alive)[0]
        # Least-squares decode: exactness (or the certified bound) is
        # asserted, so the returned vector is always usable.
        ones = np.ones(self.K)
        a_idx, *_ = np.linalg.lstsq(self.B[idx].T, ones, rcond=None)
        resid = np.linalg.norm(self.B[idx].T @ a_idx - ones)
        if resid > self._decode_tol():
            raise ValueError(
                f"alive set {idx.tolist()} is not decodable "
                f"(residual {resid:.3g} > certified {self._decode_tol():.3g})"
            )
        a = np.zeros(self.K)
        a[idx] = a_idx
        return a

    def decode_error(self, alive: np.ndarray) -> float:
        """Residual ||a^T B - 1^T||_2 of the lstsq decode for ``alive``.

        Zero (to fp) for exact families with >= R alive; bounded by
        ``err_bound`` for any accepted pattern of a partial-recovery
        family (the residual is non-increasing in the alive set).
        """
        a = self.decode_vector(alive)
        return float(np.linalg.norm(a @ self.B - np.ones(self.K)))

    def decode(self, messages: np.ndarray, alive: np.ndarray) -> np.ndarray:
        """Full-batch gradient sum from alive coded messages.

        ``messages``: (K, ...) coded gradients (rows for dead ECNs
        ignored). Returns sum_t partial_grads[t] (shape =
        messages.shape[1:]), exactly for exact families and within
        ``err_bound * ||g||`` per coordinate otherwise.
        """
        a = self.decode_vector(alive)
        m = np.asarray(messages).reshape(self.K, -1)
        return (a @ m).reshape(np.asarray(messages).shape[1:])

    def _patterns(self, n_dead: int, max_patterns: int, rng):
        """Alive masks with exactly ``n_dead`` dead ECNs (exhaustive when
        C(K, n_dead) <= max_patterns, else a seeded random sample)."""
        if n_dead == 0:
            deads = [()]
        elif _ncr(self.K, n_dead) <= max_patterns:
            deads = itertools.combinations(range(self.K), n_dead)
        else:
            rng = rng or np.random.default_rng(0)
            deads = [
                tuple(rng.choice(self.K, size=n_dead, replace=False))
                for _ in range(max_patterns)
            ]
        for dead in deads:
            alive = np.ones(self.K, dtype=bool)
            alive[list(dead)] = False
            yield alive

    def verify(
        self,
        max_patterns: int = 4096,
        rng: Optional[np.random.Generator] = None,
    ) -> bool:
        """Certify decodability of every accepted straggler pattern.

        Patterns of exactly S dead ECNs and — for partial-recovery
        families — the worst accepted patterns of K - r_min dead must
        all decode within the family's certified tolerance (exactly for
        exact families, within ``err_bound`` otherwise; the ISSUE/test
        contract is "exact, or within the certified bound"). Exhaustive
        when the pattern count is small, else sampled.
        """
        checks = [self.S]
        if self.min_responses < self.R:
            checks.append(self.K - self.min_responses)
        for n_dead in checks:
            for alive in self._patterns(n_dead, max_patterns, rng):
                try:
                    self.decode_vector(alive)
                except ValueError:
                    return False
        return True


def _ncr(n: int, r: int) -> int:
    import math

    return math.comb(n, r)


# --------------------------------------------------------------------------
# Constructions
# --------------------------------------------------------------------------


def fractional_repetition_code(K: int, S: int) -> GradientCode:
    """Fractional repetition scheme of [23] (requires (S+1) | K)."""
    _check_KS(K, S, "fractional")
    if K % (S + 1) != 0:
        raise ValueError(
            f"fractional repetition needs (S+1) | K; got K={K}, S={S}"
        )
    m = K // (S + 1)  # workers per group
    B = np.zeros((K, K))
    for g in range(S + 1):  # group index
        for j in range(m):  # member index within group
            worker = g * m + j
            parts = np.arange(j * (S + 1), (j + 1) * (S + 1))
            B[worker, parts] = 1.0
    return GradientCode("fractional", K, S, B)


def _cyclic_B(K: int, S: int, seed: int, max_tries: int) -> np.ndarray:
    """The certified cyclic-support encode matrix (shared by the cyclic
    and approx families)."""
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        # H in R^{S x K} with H @ 1 = 0; rowspan(B) = null(H) which
        # contains the all-ones vector (Tandon et al., randomized).
        H = rng.standard_normal((S, K))
        H[:, -1] -= H.sum(axis=1)
        B = np.zeros((K, K))
        ok = True
        for j in range(K):
            cols = (j + np.arange(S + 1)) % K
            Hs = H[:, cols]  # (S, S+1): 1-dim null space generically
            _, sv, Vt = np.linalg.svd(Hs)
            if S > 0 and sv[-1] < 1e-10:
                ok = False  # degenerate draw; retry
                break
            coef = Vt[-1]  # null vector of Hs
            # Scale so coefficients sum to S+1 (matches the uncoded
            # convention where each row "covers" S+1 partitions; any
            # nonzero scale works for decodability).
            ssum = coef.sum()
            if abs(ssum) < 1e-10:
                ok = False
                break
            coef = coef * ((S + 1) / ssum)
            B[j, cols] = coef
        if ok and GradientCode("cyclic", K, S, B).verify():
            return B
    raise RuntimeError(
        f"failed to draw a decodable cyclic code for K={K}, S={S}"
    )


def cyclic_repetition_code(
    K: int, S: int, seed: int = 0, max_tries: int = 16
) -> GradientCode:
    """Cyclic repetition scheme of [23] (randomized construction,
    certified via :meth:`GradientCode.verify` before returning)."""
    _check_KS(K, S, "cyclic")
    if S == 0:
        return GradientCode("cyclic", K, 0, np.eye(K))
    return GradientCode("cyclic", K, S, _cyclic_B(K, S, seed, max_tries))


def mds_code(K: int, S: int, seed: int = 0) -> GradientCode:
    """Real-field MDS gradient code: Vandermonde encode, lstsq decode.

    B = W @ V where W is the (K, R) Vandermonde matrix on Chebyshev
    nodes (any R of its rows are invertible — distinct real nodes) and
    V is an (R, K) orthonormal row basis whose span contains 1_K. For
    ANY alive set with >= R responses, B[alive] = W[alive] @ V has
    rowspan(V) as its rowspan, so the all-ones decode target is always
    reachable: exact decode from *any* R-subset, not just the fastest.
    The price is dense rows — replication = K (every ECN computes every
    partition), the MDS end of the storage/flexibility frontier.
    """
    _check_KS(K, S, "mds")
    R = K - S
    # Chebyshev nodes keep the real Vandermonde well conditioned at the
    # K <= O(16) ECN counts this simulator sweeps.
    nodes = np.cos((2 * np.arange(K) + 1) * np.pi / (2 * K))
    W = np.vander(nodes, R, increasing=True)  # (K, R)
    rng = np.random.default_rng(seed)
    basis = np.concatenate(
        [np.ones((K, 1)) / np.sqrt(K), rng.standard_normal((K, R - 1))],
        axis=1,
    )
    V = np.linalg.qr(basis)[0].T  # (R, K), rowspan contains 1_K
    code = GradientCode("mds", K, S, W @ V)
    if not code.verify():  # pragma: no cover - deterministic construction
        raise RuntimeError(f"mds construction failed for K={K}, S={S}")
    return code


def approx_code(
    K: int, S: int, seed: int = 0, max_patterns: int = 4096
) -> GradientCode:
    """Partial-recovery gradient code with a certified error bound.

    Storage and exact-decode behavior are identical to the cyclic
    scheme (same certified B, support size S+1, exact from any
    R = K - S responses), but decode is additionally defined down to
    r_min = max(1, K - 2S) responses via least squares. ``err_bound``
    is the exact worst-case residual ||a^T B - 1^T||_2 over ALL
    r_min-size alive patterns when their count is <= ``max_patterns``
    (every K this simulator sweeps); above that, enumeration is skipped
    and the *provable* bound ||1||_2 = sqrt(K) is certified instead
    (a = 0 is feasible, lstsq only improves on it) — loose, but an
    unsampled runtime pattern can never exceed it and crash a schedule
    mid-sweep. This is the bounded-error decode the deadline path of
    `repro.core.timing.TimingModel` selects when fewer than R ECNs
    respond in time (DESIGN.md §11).
    """
    _check_KS(K, S, "approx")
    if S < 1:
        raise ValueError(
            f"approx (partial recovery) needs S >= 1; got K={K}, S={S}"
        )
    B = _cyclic_B(K, S, seed, max_tries=16)
    r_min = max(1, K - 2 * S)
    if _ncr(K, K - r_min) > max_patterns:
        return GradientCode(
            "approx", K, S, B, r_min=r_min, err_bound=float(np.sqrt(K))
        )
    ones = np.ones(K)
    worst = 0.0
    probe = GradientCode("approx", K, S, B, r_min=r_min, err_bound=np.inf)
    for alive in probe._patterns(K - r_min, max_patterns, None):
        idx = np.nonzero(alive)[0]
        a, *_ = np.linalg.lstsq(B[idx].T, ones, rcond=None)
        worst = max(worst, float(np.linalg.norm(B[idx].T @ a - ones)))
    return GradientCode("approx", K, S, B, r_min=r_min, err_bound=worst)


def uncoded(K: int) -> GradientCode:
    """Disjoint allocation (sI-ADMM, Algorithm 1): B = I, must wait for
    all K ECNs."""
    return GradientCode("uncoded", K, 0, np.eye(K))


def paper_fig2_code() -> GradientCode:
    """The exact (K=3, S=1) example of the paper's Fig. 2."""
    B = np.array(
        [
            [0.5, 1.0, 0.0],
            [0.0, 1.0, -1.0],
            [0.5, 0.0, 1.0],
        ]
    )
    return GradientCode("cyclic", 3, 1, B)


def _check_KS(K: int, S: int, name: str) -> None:
    """The shared (K, S) range check — one message format for both the
    `make_code` registry path and direct builder calls."""
    if K < 1 or S < 0 or S >= K:
        raise ValueError(
            f"{name!r} code infeasible: need 0 <= S < K "
            f"(got K={K}, S={S})"
        )


# --------------------------------------------------------------------------
# Family registry (DESIGN.md §11)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CodeFamily:
    """One registered construction: feasibility rule + certified builder.

    Attributes:
      name: registry key (= `GradientCode.name` of built codes).
      exact: True iff every accepted pattern decodes exactly (err_bound
        is identically 0); partial-recovery families set False.
      replication: human-readable storage overhead formula, for docs
        and the README's family-selection table.
      build: ``(K, S, seed) -> GradientCode`` (certified on return).
      feasible: ``(K, S) -> Optional[str]`` — None when (K, S) is
        constructible, else the reason, which `make_code` turns into a
        uniform, actionable ValueError *before* any construction math
        can fail cryptically.
    """

    name: str
    exact: bool
    replication: str
    build: "object"
    feasible: "object"

    def check(self, K: int, S: int) -> None:
        """Raise the family's feasibility error for (K, S), if any."""
        _check_KS(K, S, self.name)
        reason = self.feasible(K, S)
        if reason is not None:
            raise ValueError(
                f"{self.name!r} code infeasible for K={K}, S={S}: {reason}"
            )


CODE_FAMILIES: Dict[str, CodeFamily] = {}


def register_family(family: CodeFamily) -> CodeFamily:
    if family.name in CODE_FAMILIES:
        raise ValueError(f"duplicate code family {family.name!r}")
    CODE_FAMILIES[family.name] = family
    return family


register_family(
    CodeFamily(
        "uncoded",
        exact=True,
        replication="1",
        build=lambda K, S, seed: uncoded(K),
        feasible=lambda K, S: (
            None if S == 0 else "uncoded tolerates no stragglers (S must be 0)"
        ),
    )
)
register_family(
    CodeFamily(
        "fractional",
        exact=True,
        replication="S+1",
        build=lambda K, S, seed: fractional_repetition_code(K, S),
        feasible=lambda K, S: (
            None
            if K % (S + 1) == 0
            else f"needs (S+1) | K, but {S + 1} does not divide {K}"
        ),
    )
)
register_family(
    CodeFamily(
        "cyclic",
        exact=True,
        replication="S+1",
        build=lambda K, S, seed: cyclic_repetition_code(K, S, seed=seed),
        feasible=lambda K, S: None,
    )
)
register_family(
    CodeFamily(
        "mds",
        exact=True,
        replication="K",
        build=lambda K, S, seed: mds_code(K, S, seed=seed),
        feasible=lambda K, S: None,
    )
)
register_family(
    CodeFamily(
        "approx",
        exact=False,
        replication="S+1",
        build=lambda K, S, seed: approx_code(K, S, seed=seed),
        feasible=lambda K, S: (
            None if S >= 1 else "partial recovery needs S >= 1"
        ),
    )
)


def make_code(scheme: str, K: int, S: int, seed: int = 0) -> GradientCode:
    """Factory over the family registry.

    Validates feasibility FIRST, so infeasible (K, S) always surfaces as
    a uniform ``ValueError: '<family>' code infeasible ...`` rather than
    a construction-internal null-space or divisibility failure.
    """
    if scheme not in CODE_FAMILIES:
        raise ValueError(
            f"unknown code family {scheme!r}; known: "
            f"{sorted(CODE_FAMILIES)}"
        )
    family = CODE_FAMILIES[scheme]
    family.check(K, S)
    return family.build(K, S, seed)


# --------------------------------------------------------------------------
# Arm sets for the online controller (DESIGN.md §15)
# --------------------------------------------------------------------------


def check_arm_set(arms, K: int) -> None:
    """Validate a controller arm set without building anything.

    ``arms`` is a sequence of ``(scheme, S, deadline)`` cells — the
    frontier coordinates the bandit of `repro.control` selects among.
    EVERY arm is checked before ANY code is constructed, so an
    infeasible cell surfaces at arm-set construction with the same
    uniform ``'<family>' code infeasible`` message `make_code` raises —
    never as a trace-time or mid-sweep failure. Also rejects empty and
    duplicate arm sets (a duplicate arm is a spec bug: the controller
    would split pulls across indistinguishable cells).
    """
    if not arms:
        raise ValueError("arm set is empty: the controller needs >= 1 arm")
    seen = set()
    for arm in arms:
        if len(arm) != 3:
            raise ValueError(
                f"arm {arm!r} is not a (scheme, S, deadline) triple"
            )
        scheme, S, deadline = arm
        if scheme not in CODE_FAMILIES:
            raise ValueError(
                f"unknown code family {scheme!r}; known: "
                f"{sorted(CODE_FAMILIES)}"
            )
        CODE_FAMILIES[scheme].check(K, int(S))
        if deadline is not None and deadline <= 0:
            raise ValueError(
                f"arm {arm!r}: deadline must be positive or None"
            )
        key = (scheme, int(S), deadline)
        if key in seen:
            raise ValueError(f"duplicate arm {arm!r} in arm set")
        seen.add(key)


def make_arm_set(arms, K: int, seed: int = 0) -> "tuple":
    """Build the certified codes of a controller arm set.

    Feasibility of the WHOLE set is pre-checked (:func:`check_arm_set`)
    before the first build, so nothing is half-constructed when a later
    arm is infeasible. Returns one `GradientCode` per arm, in arm order.
    """
    check_arm_set(arms, K)
    return tuple(
        make_code(scheme, K, int(S), seed=seed) for scheme, S, _ in arms
    )
