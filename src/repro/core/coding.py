"""(K, R) MDS gradient coding over the real field — paper §III-B.

Implements the two repetition schemes of Tandon et al. [23] that the paper
adopts for csI-ADMM (Algorithm 2):

- **Fractional repetition**: deterministic 0/1 encoding. The K ECNs are split
  into (S+1) groups of K/(S+1); each group disjointly covers all K data
  partitions, so every partition is replicated (S+1) times. Any K-S alive
  ECNs contain at least one intact group (pigeonhole), whose indicator is the
  decode vector.
- **Cyclic repetition**: ECN j holds partitions {j, j+1, ..., j+S} (mod K).
  Tandon et al.'s randomized construction: draw H in R^{S x K} with H @ 1 = 0;
  row j of B is the (generically unique) vector in null(H) supported on
  {j, ..., j+S}. Then rowspan(B) = null(H) contains the all-ones vector and
  any K-S rows span it (general position), so any R = K-S responses decode
  exactly — we *verify* this at construction time and re-draw on failure, so
  the returned code is certified.

The paper's Fig. 2 example (K=3, S=1) is the cyclic scheme:
    g1 = 1/2 g~1 + g~2 ,  g2 = g~2 - g~3 ,  g3 = 1/2 g~1 + g~3
and any two responses recover g~1 + g~2 + g~3 exactly.

Encoding/decoding are linear maps over stacked partition gradients, so the
same matrices drive both the faithful simulator (`repro.core.admm`) and the
TPU mesh runtime (`repro.distributed.coded_grad`), where decode becomes a
masked weighted all-reduce and the combine is fused by the
`repro.kernels.coded_combine` Pallas kernel.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

__all__ = [
    "GradientCode",
    "make_code",
    "fractional_repetition_code",
    "cyclic_repetition_code",
    "uncoded",
    "paper_fig2_code",
]


@dataclasses.dataclass(frozen=True)
class GradientCode:
    """A certified (K, R) gradient code.

    Attributes:
      name: scheme name ("fractional", "cyclic", "uncoded").
      K: number of ECNs (= number of data partitions, d = n in [23]).
      S: number of tolerated stragglers; R = K - S responses suffice.
      B: (K, K) encode matrix. ECN j transmits ``B[j] @ partial_grads`` where
        ``partial_grads`` stacks the K per-partition gradients. Row support
        of B[j] is the set of partitions ECN j must store/compute.
    """

    name: str
    K: int
    S: int
    B: np.ndarray  # (K, K) float64

    @property
    def R(self) -> int:
        return self.K - self.S

    def support(self, j: int) -> np.ndarray:
        """Partition indices ECN j computes gradients for."""
        return np.nonzero(np.abs(self.B[j]) > 1e-12)[0]

    @property
    def replication(self) -> int:
        """Max #partitions per ECN (storage/compute overhead factor)."""
        return int(max(len(self.support(j)) for j in range(self.K)))

    def encode(self, partial_grads: np.ndarray) -> np.ndarray:
        """Coded messages from stacked per-partition gradients (K, ...)."""
        g = np.asarray(partial_grads)
        return np.tensordot(self.B, g.reshape(self.K, -1), axes=1).reshape(
            g.shape
        )

    def decode_vector(self, alive: np.ndarray) -> np.ndarray:
        """a with a^T B = 1^T and a supported on alive ECNs.

        ``alive`` is a boolean mask of length K with >= R True entries.
        Raises ValueError if the alive set cannot decode (should not happen
        for a certified code with >= R alive).
        """
        alive = np.asarray(alive, dtype=bool)
        if alive.sum() < self.R:
            raise ValueError(
                f"need >= R={self.R} responses, got {int(alive.sum())}"
            )
        idx = np.nonzero(alive)[0]
        # Solve B[idx]^T a_idx = 1 in the least-squares sense; exactness is
        # asserted (certified codes always decode exactly).
        ones = np.ones(self.K)
        a_idx, *_ = np.linalg.lstsq(self.B[idx].T, ones, rcond=None)
        resid = self.B[idx].T @ a_idx - ones
        if np.max(np.abs(resid)) > 1e-6:
            raise ValueError(f"alive set {idx.tolist()} is not decodable")
        a = np.zeros(self.K)
        a[idx] = a_idx
        return a

    def decode(self, messages: np.ndarray, alive: np.ndarray) -> np.ndarray:
        """Exact full-batch gradient sum from alive coded messages.

        ``messages``: (K, ...) coded gradients (rows for dead ECNs ignored).
        Returns sum_t partial_grads[t] (shape = messages.shape[1:]).
        """
        a = self.decode_vector(alive)
        m = np.asarray(messages).reshape(self.K, -1)
        return (a @ m).reshape(np.asarray(messages).shape[1:])

    def verify(self, max_patterns: int = 4096, rng: Optional[np.random.Generator] = None) -> bool:
        """Check decodability for straggler patterns of size exactly S.

        Exhaustive when C(K, S) <= max_patterns, else a random sample.
        """
        if self.S == 0:
            patterns = [()]
        else:
            n_comb = _ncr(self.K, self.S)
            if n_comb <= max_patterns:
                patterns = itertools.combinations(range(self.K), self.S)
            else:
                rng = rng or np.random.default_rng(0)
                patterns = [
                    tuple(rng.choice(self.K, size=self.S, replace=False))
                    for _ in range(max_patterns)
                ]
        for dead in patterns:
            alive = np.ones(self.K, dtype=bool)
            alive[list(dead)] = False
            try:
                self.decode_vector(alive)
            except ValueError:
                return False
        return True


def _ncr(n: int, r: int) -> int:
    import math

    return math.comb(n, r)


def fractional_repetition_code(K: int, S: int) -> GradientCode:
    """Fractional repetition scheme of [23] (requires (S+1) | K)."""
    if S < 0 or S >= K:
        raise ValueError(f"need 0 <= S < K, got K={K}, S={S}")
    if K % (S + 1) != 0:
        raise ValueError(
            f"fractional repetition needs (S+1) | K; got K={K}, S={S}"
        )
    m = K // (S + 1)  # workers per group
    B = np.zeros((K, K))
    for g in range(S + 1):  # group index
        for j in range(m):  # member index within group
            worker = g * m + j
            parts = np.arange(j * (S + 1), (j + 1) * (S + 1))
            B[worker, parts] = 1.0
    return GradientCode("fractional", K, S, B)


def cyclic_repetition_code(
    K: int, S: int, seed: int = 0, max_tries: int = 16
) -> GradientCode:
    """Cyclic repetition scheme of [23] (randomized construction, certified).

    ECN j covers partitions {j, ..., j+S} (mod K) with random coefficients;
    we rescale rows so that B @ 1 = (S+1)-ish is irrelevant — decodability is
    what is certified via :meth:`GradientCode.verify`.
    """
    if S < 0 or S >= K:
        raise ValueError(f"need 0 <= S < K, got K={K}, S={S}")
    if S == 0:
        return GradientCode("cyclic", K, 0, np.eye(K))
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        # H in R^{S x K} with H @ 1 = 0; rowspan(B) = null(H) which contains
        # the all-ones vector (Tandon et al., randomized construction).
        H = rng.standard_normal((S, K))
        H[:, -1] -= H.sum(axis=1)
        B = np.zeros((K, K))
        ok = True
        for j in range(K):
            cols = (j + np.arange(S + 1)) % K
            Hs = H[:, cols]  # (S, S+1): 1-dim null space generically
            _, sv, Vt = np.linalg.svd(Hs)
            if S > 0 and sv[-1] < 1e-10:
                ok = False  # degenerate draw; retry
                break
            coef = Vt[-1]  # null vector of Hs
            # Scale so that coefficients sum to S+1 (matches the uncoded
            # convention where each row "covers" S+1 partitions; any nonzero
            # scale works for decodability).
            ssum = coef.sum()
            if abs(ssum) < 1e-10:
                ok = False
                break
            coef = coef * ((S + 1) / ssum)
            B[j, cols] = coef
        if not ok:
            continue
        code = GradientCode("cyclic", K, S, B)
        if code.verify():
            return code
    raise RuntimeError(
        f"failed to draw a decodable cyclic code for K={K}, S={S}"
    )


def uncoded(K: int) -> GradientCode:
    """Disjoint allocation (sI-ADMM, Algorithm 1): B = I, must wait for all."""
    return GradientCode("uncoded", K, 0, np.eye(K))


def paper_fig2_code() -> GradientCode:
    """The exact (K=3, S=1) example of the paper's Fig. 2."""
    B = np.array(
        [
            [0.5, 1.0, 0.0],
            [0.0, 1.0, -1.0],
            [0.5, 0.0, 1.0],
        ]
    )
    return GradientCode("cyclic", 3, 1, B)


def make_code(scheme: str, K: int, S: int, seed: int = 0) -> GradientCode:
    """Factory: scheme in {"fractional", "cyclic", "uncoded"}."""
    if scheme == "fractional":
        return fractional_repetition_code(K, S)
    if scheme == "cyclic":
        return cyclic_repetition_code(K, S, seed=seed)
    if scheme == "uncoded":
        if S != 0:
            raise ValueError("uncoded scheme tolerates no stragglers (S=0)")
        return uncoded(K)
    raise ValueError(f"unknown scheme {scheme!r}")
