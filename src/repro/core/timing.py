"""Unified simulated wall-clock timing model — every method kernel's clock.

The paper's headline comparisons (Figs. 3(e), 4; §V-A) are on *running
time*: communication time among agents (per-link uniform U(comm_lo,
comm_hi) seconds) plus per-iteration compute/response time. One
`TimingModel` instance is consumed by every `MethodKernel.prepare`
(DESIGN.md §10), so the accuracy-vs-time axis is comparable across the
whole registry:

- **ADMM family** (sI-/csI-/I-/pI-/cq-sI-ADMM): per-activation time =
  ECN response (R-th fastest for coded, epsilon-capped slowest for
  uncoded — with the true wait recorded when *no* ECN beats the cap)
  plus one token-hop link time, scaled by the token's true bit cost for
  compressed variants (`repro.core.admm.make_schedule`).
- **Gossip** (D-ADMM/DGD/EXTRA): per-round time = slowest-agent compute
  plus the slowest agent's serialized per-neighbor link transfers
  (:meth:`TimingModel.gossip_round_times`).
- **W-ADMM**: per-walk-step time = active-agent compute plus one link
  hop (:meth:`TimingModel.walk_step_times`).

Heterogeneous-fleet knobs: ``speed_classes`` assigns per-worker speed
factors round-robin (worker w runs ``speed_classes[w % len]`` times
slower than the homogeneous base), and ``response`` switches the base
compute draw between the paper's uniform model and the shifted
exponential of the coded-computing literature (response-time-aware edge
models, arXiv 2107.00481). Straggler events stay an *additive*
exponential delay on top — transient network/queueing stalls, not a
property of the machine class, so they are deliberately not scaled.

Event-driven mode (DESIGN.md §13): ``tau_max``/``churn_rate`` switch the
model from bulk-synchronous rounds to bounded-staleness updates and
elastic fleets — the dynamic-network settings surveyed in arXiv
1503.08855 and the edge-IIoT regime of arXiv 2107.00481. Both are
*pre-sampled schedules*: :meth:`staleness_steps` maps per-update
simulated delays tau ~ U(0, tau_max] onto integer step delays against a
run's cumulative clock, and :meth:`sample_churn` realizes a
crash/recover alternating-renewal process per worker on the same clock.
Kernels thread the resulting arrays through their scan as runtime data
(the PR-5 mask pattern), so asynchrony never retraces. ``tau_max = 0``
and ``churn_rate = 0`` (the defaults) keep every method on the exact
bulk-synchronous code path, bit for bit.

All times are *simulated* (the container has no cluster — the paper
itself simulates delays on a laptop), and every draw happens HOST-side
in ``prepare`` so device steps stay pure (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = ["TimingModel", "StragglerModel", "sample_times"]

_RESPONSES = ("uniform", "shifted_exp", "lognormal", "pareto")


@dataclasses.dataclass(frozen=True)
class TimingModel:
    """Per-worker compute/response-time distribution with planted stragglers.

    Every worker (ECN or agent) draws a base compute time — uniform
    U(base_lo, base_hi), or base_lo + Exp(mean=base_hi - base_lo) when
    ``response="shifted_exp"`` — multiplied by its speed-class factor.
    The heavy-tailed fleet models share the same floor and *mean excess*
    (base_hi - base_lo), so curves across response models compare at
    equal average compute: ``"lognormal"`` draws the excess from a
    mean-1 log-normal (sigma=1, mu=-1/2 — moderate tail, finite
    variance) and ``"pareto"`` from a mean-1 Lomax (shape a=2 — the
    edge-fleet regime with INFINITE variance, where a handful of workers
    dominate every round and coding must pay off).
    In each iteration, each worker independently straggles with
    probability ``p_straggle``; stragglers add a delay ~ Exp(mean=delay).
    ``epsilon`` caps how long an uncoded agent will wait for its ECNs
    (the paper's maximum delay parameter); it does not apply to workers
    nobody can drop (gossip rounds, walk steps, the no-response
    fallback).

    ``tau_max`` bounds the simulated delay of an *update landing*: each
    transmitted update is delayed by tau ~ U(0, tau_max] seconds and
    applied at the last iteration boundary within that window, so the
    realized staleness never exceeds ``tau_max`` (DESIGN.md §13).
    ``churn_rate`` is each worker's crash intensity (expected crashes
    per simulated second while up); ``mttr`` the mean time-to-recovery
    (0 = crashed workers never rejoin). ``staleness_cap`` bounds the
    ring-buffer depth of in-flight updates a kernel carries — delays are
    additionally clipped to ``staleness_cap - 1`` steps, which only ever
    *shortens* a delay, so the tau_max bound survives the clip.

    ``deadline`` is the per-iteration *decode deadline* (DESIGN.md §11):
    when set and the gradient code supports partial recovery
    (``code.min_responses < code.R``), a coded agent decodes at the
    deadline from whatever >= r_min responses have arrived — with the
    code's certified bounded error — instead of waiting for the R-th
    ECN; exact decode still wins whenever the R-th response beats the
    deadline, and a deadline that catches < r_min responses falls back
    to the exact wait. Exact-only code families ignore it entirely.
    """

    base_lo: float = 1e-4
    base_hi: float = 2e-4
    p_straggle: float = 0.1
    delay: float = 5e-3
    epsilon: float = 1e-2
    comm_lo: float = 1e-5  # per-link agent<->agent token time (paper §V-A)
    comm_hi: float = 1e-4
    # Heterogeneous fleet: worker w is speed_classes[w % len] x slower.
    speed_classes: Tuple[float, ...] = (1.0,)
    response: str = "uniform"  # one of _RESPONSES
    # Decode deadline for partial-recovery codes (None = wait for R).
    deadline: Optional[float] = None
    # Event-driven mode (DESIGN.md §13): staleness bound, churn process.
    tau_max: float = 0.0  # max simulated update delay; 0 = synchronous
    churn_rate: float = 0.0  # crashes per sim-second per worker; 0 = none
    mttr: float = 0.0  # mean time-to-recovery; 0 = crashes are permanent
    staleness_cap: int = 8  # ring-buffer depth D; step delays < D

    def __post_init__(self) -> None:
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError(
                f"deadline must be positive or None, got {self.deadline}"
            )
        if self.tau_max < 0 or self.churn_rate < 0 or self.mttr < 0:
            raise ValueError(
                "tau_max, churn_rate, mttr must be >= 0, got "
                f"({self.tau_max}, {self.churn_rate}, {self.mttr})"
            )
        if self.staleness_cap < 2:
            raise ValueError(
                f"staleness_cap must be >= 2, got {self.staleness_cap}"
            )
        if self.response not in _RESPONSES:
            raise ValueError(
                f"unknown response model {self.response!r}; "
                f"known: {_RESPONSES}"
            )
        if not self.speed_classes or any(
            s <= 0 for s in self.speed_classes
        ):
            raise ValueError(
                f"speed_classes must be positive, got {self.speed_classes}"
            )

    @property
    def is_async(self) -> bool:
        """True when the event-driven mode is on (DESIGN.md §13): any
        staleness bound or churn process switches a kernel onto its
        ring-buffered async path and its own static signature."""
        return self.tau_max > 0 or self.churn_rate > 0

    # -- worker-level draws ------------------------------------------------

    def speed_factors(self, n: int) -> np.ndarray:
        """(n,) per-worker slowdown factors, classes assigned round-robin."""
        return np.resize(np.asarray(self.speed_classes, dtype=float), n)

    def sample_ecn_times(
        self, iters: int, K: int, rng: np.random.Generator
    ) -> np.ndarray:
        """(iters, K) per-worker times (uncapped; caller applies epsilon).

        Also the per-agent compute model of the gossip/walk baselines —
        one worker is one unit of local computation, whoever runs it.
        Draw order (base, straggle mask, delay) is part of the seed
        contract: homogeneous-uniform draws are bit-identical to the
        original `StragglerModel`.
        """
        scale = self.base_hi - self.base_lo
        if self.response == "uniform":
            base = rng.uniform(self.base_lo, self.base_hi, size=(iters, K))
        elif self.response == "shifted_exp":
            # Same support floor, exponential tail.
            base = self.base_lo + rng.exponential(scale, size=(iters, K))
        elif self.response == "lognormal":
            # Mean-1 log-normal excess (mu = -sigma^2/2, sigma = 1), so
            # E[base] matches shifted_exp at every scale.
            base = self.base_lo + scale * rng.lognormal(
                mean=-0.5, sigma=1.0, size=(iters, K)
            )
        else:  # pareto: mean-1 Lomax (shape 2), infinite variance
            base = self.base_lo + scale * rng.pareto(2.0, size=(iters, K))
        straggle = rng.random((iters, K)) < self.p_straggle
        extra = rng.exponential(self.delay, size=(iters, K))
        return base * self.speed_factors(K)[None, :] + straggle * extra

    def sample_link_times(
        self, iters, rng: np.random.Generator
    ) -> np.ndarray:
        """Per-hop token communication times; ``iters`` may be a shape."""
        return rng.uniform(self.comm_lo, self.comm_hi, size=iters)

    # -- per-kernel composite clocks (DESIGN.md §10) -----------------------

    def gossip_components(
        self, net, iters: int, rng: np.random.Generator
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(comp (iters, N), per_agent_link (iters, N)) round ingredients.

        Split out of :meth:`gossip_round_times` so the async path can
        draw ONCE and then evaluate the round under different alive
        masks (the churn grid is built on the churn-free clock,
        DESIGN.md §13) without perturbing the seed contract.
        """
        comp = self.sample_ecn_times(iters, net.N, rng)
        link = self.sample_link_times((iters, net.E), rng)
        inc = np.zeros((net.E, net.N))
        for e, (i, j) in enumerate(net.edges):
            inc[e, i] = inc[e, j] = 1.0
        return comp, link @ inc

    def gossip_round_times(
        self, net, iters: int, rng: np.random.Generator, alive=None
    ) -> np.ndarray:
        """(iters,) round times for all-agents-per-step gossip methods.

        A round completes when the slowest agent has (a) computed its
        local update and (b) pushed one message to each neighbor; an
        agent's sends serialize over its uplink while distinct agents
        transmit concurrently, so the link term is the *max over agents*
        of the sum of their incident per-edge times. With an ``alive``
        (iters, N) mask, crashed agents neither compute nor transmit —
        the round completes when the slowest *alive* agent does, floored
        at ``base_lo`` so the clock stays strictly increasing even
        through an all-crashed round (DESIGN.md §13).
        """
        comp, per_agent = self.gossip_components(net, iters, rng)
        return self.gossip_round_from(comp, per_agent, alive)

    def gossip_round_from(
        self, comp: np.ndarray, per_agent: np.ndarray, alive=None
    ) -> np.ndarray:
        """Round times from pre-drawn :meth:`gossip_components`."""
        if alive is None:
            return comp.max(axis=1) + per_agent.max(axis=1)
        up = np.asarray(alive, dtype=bool)
        rt = np.where(up, comp, 0.0).max(axis=1) + np.where(
            up, per_agent, 0.0
        ).max(axis=1)
        return np.maximum(rt, self.base_lo)

    def walk_step_times(
        self, net, agents: np.ndarray, rng: np.random.Generator
    ) -> np.ndarray:
        """(iters,) W-ADMM step times: active-agent compute + one hop.

        The walk has no redundancy, so a straggling active agent blocks
        the token for its full delay — the honest exposure the coded
        methods are designed to avoid.
        """
        iters = len(agents)
        comp = self.sample_ecn_times(iters, net.N, rng)
        link = self.sample_link_times(iters, rng)
        return comp[np.arange(iters), np.asarray(agents, dtype=int)] + link

    # -- observed-response reward surface (DESIGN.md §15) ------------------

    @property
    def reward_cap(self) -> float:
        """Largest per-iteration wall-clock the reward surface resolves.

        ``epsilon`` (the longest an agent waits before the capped/fallback
        decode) plus one worst-case token hop ``comm_hi`` — both MODEL
        knobs, not properties of the hidden response distribution, so the
        controller may use the cap without peeking at the answer.
        """
        return self.epsilon + self.comm_hi

    def reward(self, dt) -> np.ndarray:
        """Per-iteration bandit reward: negative observed wall-clock,
        affinely mapped into [0, 1] (what UCB1/EXP3 confidence terms
        assume). ``dt`` is the observed iteration time (response + link);
        times at/above :attr:`reward_cap` clip to reward 0, an instant
        iteration scores 1. Monotone decreasing in ``dt``, so maximizing
        cumulative reward minimizes simulated running time.
        """
        d = np.clip(np.asarray(dt, dtype=float), 0.0, self.reward_cap)
        return 1.0 - d / self.reward_cap

    # -- event-driven schedules (DESIGN.md §13) ----------------------------

    def staleness_steps(
        self, times: np.ndarray, rng: np.random.Generator, n: int = 0
    ) -> np.ndarray:
        """Integer step delays under the bounded-staleness model.

        ``times`` is a run's cumulative clock (iters,), ``times[k]`` the
        simulated completion time of iteration k. The update emitted at
        iteration k is delayed by tau_k ~ U(0, tau_max] and lands at the
        LAST iteration boundary <= times[k] + tau_k, so the realized
        delay never exceeds ``tau_max`` — the hard bound of DESIGN.md
        §13 — and tau_max = 0 degenerates to delay 0 (land within the
        emitting iteration, the synchronous semantics). Delays are then
        clipped to ``staleness_cap - 1`` steps (the ring-buffer depth),
        which again only shortens them. Returns (iters,) int32, or
        (iters, n) with one independent delay per worker when ``n > 0``.
        """
        iters = len(times)
        shape = (iters, n) if n else (iters,)
        if self.tau_max <= 0:
            return np.zeros(shape, dtype=np.int32)
        tau = rng.uniform(0.0, self.tau_max, size=shape)
        land = (times[:, None] if n else times) + tau
        j = np.searchsorted(times, land.ravel(), side="right") - 1
        k = np.arange(iters)[:, None] if n else np.arange(iters)
        delta = j.reshape(shape) - k
        return np.clip(delta, 0, self.staleness_cap - 1).astype(np.int32)

    def sample_churn(
        self, starts: np.ndarray, n: int, rng: np.random.Generator
    ) -> np.ndarray:
        """(iters, n) bool up/down mask of an elastic fleet.

        Each worker alternates up-times ~ Exp(mean = 1/churn_rate) and
        down-times ~ Exp(mean = mttr) in continuous simulated time (an
        alternating-renewal crash/recover process; with ``mttr = 0`` the
        first crash is permanent — the worker *leaves*). The process is
        evaluated at ``starts`` — each iteration's simulated start time
        — so a worker crashed when an iteration begins sits that whole
        iteration out. Draw order (per worker: up, down, up, ...) is
        part of the seed contract (DESIGN.md §13).
        """
        iters = len(starts)
        up = np.ones((iters, n), dtype=bool)
        if self.churn_rate <= 0:
            return up
        horizon = float(starts[-1]) if iters else 0.0
        for w in range(n):
            toggles = []
            t, is_up = 0.0, True
            while t <= horizon:
                if is_up:
                    t += rng.exponential(1.0 / self.churn_rate)
                else:
                    t += rng.exponential(self.mttr)
                toggles.append(t)
                if is_up and self.mttr <= 0:
                    break  # permanent crash: no recovery draw
                is_up = not is_up
            cnt = np.searchsorted(np.asarray(toggles), starts, side="right")
            up[:, w] = cnt % 2 == 0
        return up


# Backwards-compatible names: the paper-era straggler model IS the
# homogeneous-uniform TimingModel (identical fields, identical draws).
StragglerModel = TimingModel


def sample_times(
    model: TimingModel, iters: int, K: int, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """(ecn_times, link_times) for one run — the ADMM schedule's draws."""
    rng = np.random.default_rng(seed)
    return model.sample_ecn_times(iters, K, rng), model.sample_link_times(
        iters, rng
    )
