"""Decentralized network topologies and token-traversal cycles — paper §II, §V-A.

The experimental network G has N agents and E = N(N-1)/2 * eta links (eta =
connectivity ratio). Token traversal patterns (Fig. 1):

  (a) Hamiltonian cycle — visits each agent exactly once per cycle;
  (b) shortest-path cycle — concatenation of shortest paths between the
      Hamiltonian order when no Hamiltonian cycle exists / as an alternative
      walking pattern (WPG-style [5]); agents may be visited more than once,
      which inflates communication cost per cycle.

All graphs are guaranteed connected (Assumption 1) by construction: we start
from a random Hamiltonian ring and add extra random edges up to the target
connectivity ratio. This both matches the paper's simulation setup and makes
Assumption 1 (existence of a Hamiltonian cycle) hold by construction.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

__all__ = ["Network", "make_network", "metropolis_weights"]


@dataclasses.dataclass(frozen=True)
class Network:
    """An undirected connected agent graph with traversal cycles."""

    N: int
    edges: Tuple[Tuple[int, int], ...]  # undirected, i < j
    hamiltonian: Tuple[int, ...]  # agent order, length N
    shortest_path_cycle: Tuple[int, ...]  # token route, length >= N

    @property
    def E(self) -> int:
        return len(self.edges)

    @property
    def adjacency(self) -> np.ndarray:
        A = np.zeros((self.N, self.N), dtype=bool)
        for i, j in self.edges:
            A[i, j] = A[j, i] = True
        return A

    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.adjacency[i])[0]

    def degree(self) -> np.ndarray:
        return self.adjacency.sum(1)


def _shortest_paths(A: np.ndarray) -> np.ndarray:
    """All-pairs hop distances (BFS per source). A: (N, N) bool."""
    N = A.shape[0]
    dist = np.full((N, N), np.inf)
    for s in range(N):
        dist[s, s] = 0
        frontier = [s]
        d = 0
        while frontier:
            d += 1
            nxt = []
            for u in frontier:
                for v in np.nonzero(A[u])[0]:
                    if dist[s, v] == np.inf:
                        dist[s, v] = d
                        nxt.append(v)
            frontier = nxt
    return dist


def _bfs_path(A: np.ndarray, s: int, t: int) -> List[int]:
    """One shortest path s -> t (list of vertices incl. both ends)."""
    N = A.shape[0]
    prev = -np.ones(N, dtype=int)
    prev[s] = s
    frontier = [s]
    while frontier and prev[t] < 0:
        nxt = []
        for u in frontier:
            for v in np.nonzero(A[u])[0]:
                if prev[v] < 0:
                    prev[v] = u
                    nxt.append(v)
        frontier = nxt
    path = [t]
    while path[-1] != s:
        path.append(int(prev[path[-1]]))
    return path[::-1]


def make_network(N: int, connectivity: float = 0.5, seed: int = 0) -> Network:
    """Random connected graph with a planted Hamiltonian ring (paper §V-A).

    Args:
      N: number of agents.
      connectivity: eta, so that E ~= eta * N(N-1)/2 (>= the ring's N edges).
      seed: PRNG seed.
    """
    if N < 3:
        raise ValueError("need N >= 3 agents")
    rng = np.random.default_rng(seed)
    order = rng.permutation(N)
    edges = set()
    for a in range(N):
        i, j = int(order[a]), int(order[(a + 1) % N])
        edges.add((min(i, j), max(i, j)))
    target = max(N, int(round(connectivity * N * (N - 1) / 2)))
    all_pairs = [(i, j) for i in range(N) for j in range(i + 1, N)]
    rng.shuffle(all_pairs)
    for i, j in all_pairs:
        if len(edges) >= target:
            break
        edges.add((i, j))
    A = np.zeros((N, N), dtype=bool)
    for i, j in edges:
        A[i, j] = A[j, i] = True

    # Shortest-path cycle: concatenate shortest paths between consecutive
    # agents of a random visiting order (WPG-style [5]). Route includes the
    # intermediate relays, so its length is >= N.
    visit = [int(v) for v in rng.permutation(N)]
    route: List[int] = [visit[0]]
    for a in range(N):
        s, t = visit[a], visit[(a + 1) % N]
        route.extend(_bfs_path(A, s, t)[1:])
    route = route[:-1]  # last hop returns to start; cycle is implicit

    return Network(
        N=N,
        edges=tuple(sorted(edges)),
        hamiltonian=tuple(int(v) for v in order),
        shortest_path_cycle=tuple(route),
    )


def metropolis_weights(net: Network) -> np.ndarray:
    """Symmetric doubly-stochastic mixing matrix W (for DGD/EXTRA baselines)."""
    A = net.adjacency
    deg = A.sum(1)
    W = np.zeros((net.N, net.N))
    for i, j in net.edges:
        w = 1.0 / (1 + max(deg[i], deg[j]))
        W[i, j] = W[j, i] = w
    np.fill_diagonal(W, 1.0 - W.sum(1))
    return W
