"""(Coded) stochastic incremental ADMM — paper Algorithms 1 & 2, eqs. (4)-(6).

Implements, as jitted ``lax.scan`` loops over iterations:

- **I-ADMM** (eq. 4, from [34]): exact x-minimization (closed form for least
  squares), incremental token traversal.
- **sI-ADMM** (Algorithm 1, eq. 5): linearized + proximal x-update with a
  mini-batch stochastic gradient assembled from K ECN partitions (eq. 6),
  tau^k = c_tau * sqrt(k), gamma^k = c_gamma / sqrt(k) (Theorem 2).
- **csI-ADMM** (Algorithm 2): ECNs compute *coded* partition gradients
  (fractional/cyclic MDS repetition schemes, `repro.core.coding`); the agent
  decodes the exact mini-batch gradient from the fastest R = K - S responses.

Straggler behaviour and decode vectors are sampled host-side per iteration
(`repro.core.straggler`) and fed to the scan as per-step inputs; the scan
itself performs the full encode -> (masked) decode computation so the coded
data path is numerically exercised, not just simulated.

Update equations (active agent i = i_k, all others frozen):

  x_i^{k+1} = (tau^k x_i^k + rho z^k + y_i^k - G_i) / (rho + tau^k)   (5a)
  y_i^{k+1} = y_i^k + rho gamma^k (z^k - x_i^{k+1})                   (5b)
  z^{k+1}   = z^k + [ (x_i^{k+1}-x_i^k) - (y_i^{k+1}-y_i^k)/rho ] / N (4c)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .coding import GradientCode, make_code
from .graph import Network
from .problems import LeastSquaresProblem
from .straggler import StragglerModel, sample_times

__all__ = ["ADMMConfig", "Trace", "run_incremental_admm", "make_schedule"]


@dataclasses.dataclass(frozen=True)
class ADMMConfig:
    """Hyper-parameters for (c)sI-ADMM (defaults follow paper §V)."""

    rho: float = 1.0
    c_tau: float = 0.1  # tau^k = c_tau * sqrt(k)
    c_gamma: float = 1.0  # gamma^k = c_gamma / sqrt(k)
    M: int = 60  # uncoded-equivalent mini-batch size per activation
    K: int = 3  # ECNs per agent
    S: int = 0  # tolerated stragglers (csI-ADMM); 0 => uncoded sI-ADMM
    scheme: str = "uncoded"  # "uncoded" | "fractional" | "cyclic"
    exact_x: bool = False  # True => I-ADMM (closed-form x-update)
    traversal: str = "hamiltonian"  # or "shortest_path"
    seed: int = 0

    @property
    def M_bar(self) -> int:
        """Straggler-constrained batch size, eq. (22): M_bar = M/(S+1)."""
        return self.M // (self.S + 1)

    def validate(self) -> None:
        if self.M % ((self.S + 1) * self.K) != 0:
            raise ValueError(
                f"M={self.M} must be divisible by (S+1)*K="
                f"{(self.S + 1) * self.K}"
            )
        if self.scheme == "uncoded" and self.S != 0:
            raise ValueError("uncoded scheme cannot tolerate stragglers")


@dataclasses.dataclass
class Trace:
    """Per-iteration experiment record (all numpy, length = iters)."""

    accuracy: np.ndarray  # eq. (23) relative error
    test_error: np.ndarray  # MSE of the token z on the test set
    comm_cost: np.ndarray  # cumulative units (1 per token hop)
    sim_time: np.ndarray  # cumulative simulated seconds
    z_err: np.ndarray  # ||z - x*|| / ||x*||
    final_x: np.ndarray  # (N, p, d)
    final_z: np.ndarray  # (p, d)


def make_schedule(
    cfg: ADMMConfig,
    net: Network,
    code: GradientCode,
    straggler: StragglerModel,
    iters: int,
    b: int,
) -> dict:
    """Host-side per-iteration schedule: agents, batches, decode vectors, time.

    Returns dict of numpy arrays consumed by the jitted scan + the
    time/communication accounting.
    """
    rng = np.random.default_rng(cfg.seed)
    K, S = cfg.K, cfg.S
    P = b // K  # partition size per ECN slot
    mu = cfg.M_bar // K  # per-partition sub-batch size
    nb = max(P // mu, 1)  # batches per partition (paper step 16)

    # --- agent traversal -------------------------------------------------
    if cfg.traversal == "hamiltonian":
        route = np.array(net.hamiltonian, dtype=np.int32)
    elif cfg.traversal == "shortest_path":
        route = np.array(net.shortest_path_cycle, dtype=np.int32)
    else:
        raise ValueError(f"unknown traversal {cfg.traversal!r}")
    reps = int(np.ceil(iters / len(route)))
    agents = np.tile(route, reps)[:iters]

    # --- mini-batch index (Algorithm 1 step 16 / Algorithm 2 step 15) ----
    cycle = np.arange(iters) // net.N  # cycle index m
    offsets = ((cycle % nb) * mu).astype(np.int32)

    # --- stragglers & decoding ------------------------------------------
    ecn_t, link_t = sample_times(straggler, iters, K, seed=cfg.seed + 1)
    decode = np.zeros((iters, K))
    resp = np.zeros(iters)
    order = np.argsort(ecn_t, axis=1)
    for k in range(iters):
        t = ecn_t[k]
        if cfg.scheme == "uncoded":
            recv = t <= straggler.epsilon
            if not recv.any():
                recv[np.argmin(t)] = True
            decode[k, recv] = K / recv.sum()
            resp[k] = min(t.max(), straggler.epsilon)
        else:
            fastest = order[k, : code.R]
            alive = np.zeros(K, dtype=bool)
            alive[fastest] = True
            decode[k] = code.decode_vector(alive)
            resp[k] = min(t[fastest].max(), straggler.epsilon)

    tau = cfg.c_tau * np.sqrt(np.arange(1, iters + 1))
    gamma = cfg.c_gamma / np.sqrt(np.arange(1, iters + 1))

    return dict(
        agents=agents,
        offsets=offsets,
        decode=decode,
        tau=tau,
        gamma=gamma,
        resp_time=resp,
        link_time=link_t,
        mu=mu,
        P=P,
    )


@partial(jax.jit, static_argnames=("mu", "P", "K", "N", "exact_x"))
def _scan_admm(
    O: jax.Array,  # (N, b, p)
    T: jax.Array,  # (N, b, d)
    B: jax.Array,  # (K, K) encode matrix
    x_star: jax.Array,  # (p, d)
    O_test: jax.Array,
    T_test: jax.Array,
    agents: jax.Array,
    offsets: jax.Array,
    decode: jax.Array,
    tau: jax.Array,
    gamma: jax.Array,
    rho: float,
    *,
    mu: int,
    P: int,
    K: int,
    N: int,
    exact_x: bool,
):
    p, d = O.shape[2], T.shape[2]
    x0 = jnp.zeros((N, p, d), O.dtype)
    y0 = jnp.zeros((N, p, d), O.dtype)
    z0 = jnp.zeros((p, d), O.dtype)
    xs_norm = jnp.linalg.norm(x_star)

    # Precomputed exact-solve operands (I-ADMM): (O^T O / b + rho I), O^T T / b
    H = jnp.einsum("nbp,nbq->npq", O, O) / O.shape[1]
    rhs0 = jnp.einsum("nbp,nbd->npd", O, T) / O.shape[1]
    eye = jnp.eye(p, dtype=O.dtype)

    def step(carry, inp):
        x, y, z = carry
        i, off, a, tk, gk = inp
        Oi = O[i]
        Ti = T[i]
        xi, yi = x[i], y[i]

        if exact_x:
            # I-ADMM exact x-update (eq. 4a) -- full-batch least squares.
            x_new = jnp.linalg.solve(
                H[i] + rho * eye, rhs0[i] + rho * z + yi
            )
        else:
            # Per-partition mini-batch gradients g~_t (Algorithms 1&2).
            def pgrad(t):
                Ob = jax.lax.dynamic_slice(Oi, (t * P + off, 0), (mu, p))
                Tb = jax.lax.dynamic_slice(Ti, (t * P + off, 0), (mu, d))
                return Ob.T @ (Ob @ xi - Tb) / mu

            gbar = jax.vmap(pgrad)(jnp.arange(K))  # (K, p, d)
            msgs = jnp.tensordot(B, gbar, axes=1)  # encode, (K, p, d)
            G = jnp.tensordot(a, msgs, axes=1) / K  # decode + eq. (6)
            # Proximal linearized x-update (eq. 5a).
            x_new = (tk * xi + rho * z + yi - G) / (rho + tk)

        y_new = yi + rho * gk * (z - x_new)  # eq. (5b)
        z_new = z + ((x_new - xi) - (y_new - yi) / rho) / N  # eq. (4c)
        x = x.at[i].set(x_new)
        y = y.at[i].set(y_new)

        acc = jnp.mean(
            jnp.linalg.norm(
                (x - x_star[None]).reshape(N, -1), axis=1
            )
            / jnp.maximum(xs_norm, 1e-12)
        )
        r = O_test @ z_new - T_test
        test_err = jnp.mean(jnp.sum(r * r, axis=-1))
        z_err = jnp.linalg.norm(z_new - x_star) / jnp.maximum(xs_norm, 1e-12)
        return (x, y, z_new), (acc, test_err, z_err)

    (x, y, z), (acc, test_err, z_err) = jax.lax.scan(
        step, (x0, y0, z0), (agents, offsets, decode, tau, gamma)
    )
    return x, z, acc, test_err, z_err


def run_incremental_admm(
    problem: LeastSquaresProblem,
    net: Network,
    cfg: ADMMConfig,
    iters: int,
    straggler: Optional[StragglerModel] = None,
    code: Optional[GradientCode] = None,
) -> Trace:
    """Run I-/sI-/csI-ADMM for ``iters`` activations and return the trace."""
    cfg.validate()
    straggler = straggler or StragglerModel()
    code = code or make_code(cfg.scheme, cfg.K, cfg.S, seed=cfg.seed)
    if code.K != cfg.K or code.S != cfg.S:
        raise ValueError("code does not match config (K, S)")

    sched = make_schedule(cfg, net, code, straggler, iters, problem.b)
    x_star = problem.x_star()

    x, z, acc, test_err, z_err = _scan_admm(
        jnp.asarray(problem.O),
        jnp.asarray(problem.T),
        jnp.asarray(code.B.astype(problem.O.dtype)),
        jnp.asarray(x_star.astype(problem.O.dtype)),
        jnp.asarray(problem.O_test),
        jnp.asarray(problem.T_test),
        jnp.asarray(sched["agents"]),
        jnp.asarray(sched["offsets"]),
        jnp.asarray(sched["decode"].astype(problem.O.dtype)),
        jnp.asarray(sched["tau"].astype(problem.O.dtype)),
        jnp.asarray(sched["gamma"].astype(problem.O.dtype)),
        float(cfg.rho),
        mu=sched["mu"],
        P=sched["P"],
        K=cfg.K,
        N=problem.N,
        exact_x=cfg.exact_x,
    )

    # One token hop per activation; response + link time per iteration.
    comm = np.cumsum(np.ones(iters))
    sim_time = np.cumsum(sched["resp_time"] + sched["link_time"])
    return Trace(
        accuracy=np.asarray(acc),
        test_error=np.asarray(test_err),
        comm_cost=comm,
        sim_time=sim_time,
        z_err=np.asarray(z_err),
        final_x=np.asarray(x),
        final_z=np.asarray(z),
    )
