"""(Coded) stochastic incremental ADMM — paper Algorithms 1 & 2, eqs. (4)-(6).

Covers, through the `repro.methods.admm.IncrementalADMM` kernel:

- **I-ADMM** (eq. 4, from [34]): exact x-minimization (closed form for least
  squares), incremental token traversal.
- **sI-ADMM** (Algorithm 1, eq. 5): linearized + proximal x-update with a
  mini-batch stochastic gradient assembled from K ECN partitions (eq. 6),
  tau^k = c_tau * sqrt(k), gamma^k = c_gamma / sqrt(k) (Theorem 2).
- **csI-ADMM** (Algorithm 2): ECNs compute *coded* partition gradients
  (fractional/cyclic MDS repetition schemes, `repro.core.coding`); the agent
  decodes the exact mini-batch gradient from the fastest R = K - S responses.

This module owns the paper-facing pieces: the hyper-parameter config, the
per-iteration trace record, and the host-side schedule sampling (agents,
batches, decode vectors, timing — `make_schedule`). The ONE device step
implementation lives in `repro.methods.admm` (DESIGN.md §8); serial and
batched execution are derived from it by `repro.methods.driver`.

Update equations (active agent i = i_k, all others frozen):

  x_i^{k+1} = (tau^k x_i^k + rho z^k + y_i^k - G_i) / (rho + tau^k)   (5a)
  y_i^{k+1} = y_i^k + rho gamma^k (z^k - x_i^{k+1})                   (5b)
  z^{k+1}   = z^k + [ (x_i^{k+1}-x_i^k) - (y_i^{k+1}-y_i^k)/rho ] / N (4c)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .coding import GradientCode
from .graph import Network
from .problems import LeastSquaresProblem
from .timing import TimingModel, sample_times

__all__ = [
    "ADMMConfig",
    "Trace",
    "run_incremental_admm",
    "make_schedule",
]


@dataclasses.dataclass(frozen=True)
class ADMMConfig:
    """Hyper-parameters for (c)sI-ADMM (defaults follow paper §V)."""

    rho: float = 1.0
    c_tau: float = 0.1  # tau^k = c_tau * sqrt(k)
    c_gamma: float = 1.0  # gamma^k = c_gamma / sqrt(k)
    M: int = 60  # uncoded-equivalent mini-batch size per activation
    K: int = 3  # ECNs per agent
    S: int = 0  # tolerated stragglers (csI-ADMM); 0 => uncoded sI-ADMM
    scheme: str = "uncoded"  # key of repro.core.coding.CODE_FAMILIES
    exact_x: bool = False  # True => I-ADMM (closed-form x-update)
    traversal: str = "hamiltonian"  # or "shortest_path"
    seed: int = 0

    @property
    def M_bar(self) -> int:
        """Straggler-constrained batch size, eq. (22): M_bar = M/(S+1)."""
        return self.M // (self.S + 1)

    def validate(self) -> None:
        if self.M % ((self.S + 1) * self.K) != 0:
            raise ValueError(
                f"M={self.M} must be divisible by (S+1)*K="
                f"{(self.S + 1) * self.K}"
            )
        if self.scheme == "uncoded" and self.S != 0:
            raise ValueError("uncoded scheme cannot tolerate stragglers")


@dataclasses.dataclass
class Trace:
    """Per-iteration experiment record (all numpy, length = iters)."""

    accuracy: np.ndarray  # eq. (23) relative error
    test_error: np.ndarray  # MSE of the token z on the test set
    comm_cost: np.ndarray  # cumulative units (1 per full token hop)
    sim_time: np.ndarray  # cumulative simulated seconds
    z_err: np.ndarray  # ||z - x*|| / ||x*||
    final_x: np.ndarray  # (N, p, d)
    final_z: np.ndarray  # (p, d)

    def reduce(self, spec) -> dict:
        """Post-hoc streaming summaries of this trace (DESIGN.md §12).

        ``spec`` is a `repro.methods.reductions.Reduction`; the result
        matches what the drivers' in-scan fold would have produced for
        the same run — the upgrade path from materialized to streaming
        sweeps, and the reference the parity tests compare against.
        """
        from repro.methods.reductions import reduce_trace  # lazy: no cycle

        return reduce_trace(spec, self)


def make_schedule(
    cfg: ADMMConfig,
    net: Network,
    code: GradientCode,
    straggler: TimingModel,
    iters: int,
    b: int,
) -> dict:
    """Host-side per-iteration schedule: agents, batches, decode vectors, time.

    Returns dict of numpy arrays consumed by the jitted scan + the
    time/communication accounting.

    With churn enabled on the timing model (DESIGN.md §13), ECNs and
    agents crash/recover as an alternating-renewal process sampled on
    the churn-free clock (seed stream [6, seed]; ECN draws before agent
    draws is part of the seed contract). Crashed ECNs never respond —
    their times are censored to +inf BEFORE the response/decode logic,
    so they are excluded from the alive mask and the per-pattern decode
    exactly like deadline-missing stragglers. Iterations whose surviving
    responses cannot be decoded (pattern below ``min_responses`` or
    outside the code family's decodable set) and iterations whose active
    agent is down are *skipped activations*: ``act = 0``, zero decode
    weights, and the token hop still pays its link time so the clock
    stays strictly increasing. An undecodable iteration records the
    epsilon cap as its wait (the agent gave up); a dead-agent iteration
    records zero compute.
    """
    K, S = cfg.K, cfg.S
    P = b // K  # partition size per ECN slot
    mu = cfg.M_bar // K  # per-partition sub-batch size
    nb = max(P // mu, 1)  # batches per partition (paper step 16)

    # --- agent traversal -------------------------------------------------
    if cfg.traversal == "hamiltonian":
        route = np.array(net.hamiltonian, dtype=np.int32)
    elif cfg.traversal == "shortest_path":
        route = np.array(net.shortest_path_cycle, dtype=np.int32)
    else:
        raise ValueError(f"unknown traversal {cfg.traversal!r}")
    reps = int(np.ceil(iters / len(route)))
    agents = np.tile(route, reps)[:iters]

    # --- mini-batch index (Algorithm 1 step 16 / Algorithm 2 step 15) ----
    cycle = np.arange(iters) // net.N  # cycle index m
    offsets = ((cycle % nb) * mu).astype(np.int32)

    # --- stragglers & decoding (vectorized over iterations) --------------
    ecn_t, link_t = sample_times(straggler, iters, K, seed=cfg.seed + 1)

    # --- churn (DESIGN.md §13): censor crashed workers -------------------
    act = np.ones(iters)
    if straggler.churn_rate > 0:
        churn_rng = np.random.default_rng([6, cfg.seed])
        # The churn process is realized on the churn-free clock (an
        # epsilon-capped provisional wait + the link hop) — documented
        # one-way approximation: crashes reshape response times, but
        # response times do not feed back into crash times.
        prov = np.cumsum(
            np.minimum(ecn_t.max(axis=1), straggler.epsilon) + link_t
        )
        starts = np.concatenate([[0.0], prov[:-1]])
        ecn_up = straggler.sample_churn(starts, K, churn_rng)
        agent_up = straggler.sample_churn(starts, net.N, churn_rng)
        act = agent_up[np.arange(iters), agents].astype(float)
        ecn_t = np.where(ecn_up, ecn_t, np.inf)

    if cfg.scheme == "uncoded":
        recv = ecn_t <= straggler.epsilon
        # nobody under the cap: wait for the fastest ECN
        none = ~recv.any(axis=1)
        all_dead = np.isinf(ecn_t).all(axis=1)
        fb = none & ~all_dead
        recv[fb, np.argmin(ecn_t[fb], axis=1)] = True
        decode = recv * (
            K / np.maximum(recv.sum(axis=1, keepdims=True), 1)
        )
        # Response = slowest counted ECN, capped at epsilon — except the
        # fallback rows, where the agent actually waited out the fastest
        # ECN's full (> epsilon) response; record that true wait.
        resp = np.minimum(ecn_t.max(axis=1), straggler.epsilon)
        resp = np.where(fb, ecn_t.min(axis=1), resp)
        if all_dead.any():  # every ECN crashed: skipped activation
            act = act * ~all_dead
            resp = np.where(all_dead, straggler.epsilon, resp)
        alive = recv
    else:
        order = np.argsort(ecn_t, axis=1)
        alive = np.zeros((iters, K), dtype=bool)
        np.put_along_axis(alive, order[:, : code.R], True, axis=1)
        # Crashed ECNs never respond: their +inf times sort last, but
        # when fewer than R survive they still land in the top-R slots —
        # strike them from the alive set so decode sees only responders.
        alive &= np.isfinite(ecn_t)
        # response time = the R-th fastest ECN, capped at epsilon
        r_th = np.take_along_axis(ecn_t, order[:, code.R - 1 : code.R], axis=1)
        resp = np.minimum(r_th[:, 0], straggler.epsilon)
        # Deadline-aware decode (DESIGN.md §11): with a partial-recovery
        # code, an iteration whose R-th response misses the deadline but
        # that has >= r_min arrivals decodes *at the deadline* from the
        # arrived set (certified bounded error) — the recorded response
        # is the deadline itself, not the R-th ECN's wait. Fewer than
        # r_min arrivals fall back to the exact wait; exact-only
        # families (min_responses == R) never take this branch.
        dl = straggler.deadline
        if dl is not None and code.min_responses < code.R:
            arrived = ecn_t <= dl
            n_arr = arrived.sum(axis=1)
            # "whichever fires first": the deadline only fires when it
            # strictly beats the exact path's recorded wait — n_arr < R
            # guarantees the R-th ECN is later, but the epsilon cap
            # could still undercut a deadline armed above epsilon.
            use_dl = (
                (n_arr >= code.min_responses)
                & (n_arr < code.R)
                & (dl < resp)
            )
            alive = np.where(use_dl[:, None], arrived, alive)
            resp = np.where(use_dl, dl, resp)
        # Decode vectors depend only on the alive pattern, so solve the
        # lstsq once per distinct pattern — a sweep samples thousands of
        # iterations but only ever sees C(K, S)-ish patterns (plus the
        # deadline-truncated and churn-censored ones). Under churn a
        # surviving pattern can fall outside the family's decodable set
        # (too few responders, or a subset the code cannot invert):
        # those iterations become skipped activations with zero decode
        # weights, recording the epsilon cap as the agent's futile wait.
        patterns, inverse = np.unique(alive, axis=0, return_inverse=True)
        vecs, decodable = [], []
        for a in patterns:
            vec = None
            if a.sum() >= code.min_responses:
                try:
                    vec = code.decode_vector(a)
                except ValueError:
                    vec = None
            decodable.append(vec is not None)
            vecs.append(vec if vec is not None else np.zeros(K))
        decode = np.stack(vecs)[inverse]
        ok = np.asarray(decodable)[inverse]
        if not ok.all():
            act = act * ok
            resp = np.where(ok, resp, straggler.epsilon)

    if straggler.churn_rate > 0:
        # Dead-agent iterations: no compute happens; the token hop alone
        # advances the clock. Zero the decode row too so the (gated)
        # device step never consumes a stale weight.
        agent_dead = act == 0.0
        resp = np.where(
            agent_up[np.arange(iters), agents], resp, 0.0
        )
        decode = np.where(agent_dead[:, None], 0.0, decode)

    tau = cfg.c_tau * np.sqrt(np.arange(1, iters + 1))
    gamma = cfg.c_gamma / np.sqrt(np.arange(1, iters + 1))

    return dict(
        agents=agents,
        offsets=offsets,
        decode=decode,
        alive=alive,
        act=act,
        tau=tau,
        gamma=gamma,
        resp_time=resp,
        link_time=link_t,
        mu=mu,
        P=P,
    )


def run_incremental_admm(
    problem: LeastSquaresProblem,
    net: Network,
    cfg: ADMMConfig,
    iters: int,
    straggler: Optional[TimingModel] = None,
    code: Optional[GradientCode] = None,
) -> Trace:
    """Run I-/sI-/csI-ADMM for ``iters`` activations and return the trace.

    Thin serial entry over the method kernel (lazy import: `repro.methods`
    imports this module for the config/trace/schedule types).
    """
    from repro.methods import get_kernel, run_serial
    from repro.methods.admm import ADMMRun

    # sI-/csI-/I-ADMM are one registered kernel instance; the behavioral
    # switches (exact_x, scheme, S) all live in cfg.
    return run_serial(
        get_kernel("sI-ADMM"), problem, net, ADMMRun(cfg, straggler, code),
        iters,
    )
