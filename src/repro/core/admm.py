"""(Coded) stochastic incremental ADMM — paper Algorithms 1 & 2, eqs. (4)-(6).

Implements, as jitted ``lax.scan`` loops over iterations:

- **I-ADMM** (eq. 4, from [34]): exact x-minimization (closed form for least
  squares), incremental token traversal.
- **sI-ADMM** (Algorithm 1, eq. 5): linearized + proximal x-update with a
  mini-batch stochastic gradient assembled from K ECN partitions (eq. 6),
  tau^k = c_tau * sqrt(k), gamma^k = c_gamma / sqrt(k) (Theorem 2).
- **csI-ADMM** (Algorithm 2): ECNs compute *coded* partition gradients
  (fractional/cyclic MDS repetition schemes, `repro.core.coding`); the agent
  decodes the exact mini-batch gradient from the fastest R = K - S responses.

Straggler behaviour and decode vectors are sampled host-side per iteration
(`repro.core.straggler`) and fed to the scan as per-step inputs; the scan
itself performs the full encode -> (masked) decode computation so the coded
data path is numerically exercised, not just simulated.

Update equations (active agent i = i_k, all others frozen):

  x_i^{k+1} = (tau^k x_i^k + rho z^k + y_i^k - G_i) / (rho + tau^k)   (5a)
  y_i^{k+1} = y_i^k + rho gamma^k (z^k - x_i^{k+1})                   (5b)
  z^{k+1}   = z^k + [ (x_i^{k+1}-x_i^k) - (y_i^{k+1}-y_i^k)/rho ] / N (4c)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .coding import GradientCode, make_code
from .graph import Network
from .problems import LeastSquaresProblem
from .straggler import StragglerModel, sample_times

__all__ = [
    "ADMMConfig",
    "Trace",
    "run_incremental_admm",
    "run_incremental_admm_batch",
    "make_schedule",
    "admm_static_signature",
]


@dataclasses.dataclass(frozen=True)
class ADMMConfig:
    """Hyper-parameters for (c)sI-ADMM (defaults follow paper §V)."""

    rho: float = 1.0
    c_tau: float = 0.1  # tau^k = c_tau * sqrt(k)
    c_gamma: float = 1.0  # gamma^k = c_gamma / sqrt(k)
    M: int = 60  # uncoded-equivalent mini-batch size per activation
    K: int = 3  # ECNs per agent
    S: int = 0  # tolerated stragglers (csI-ADMM); 0 => uncoded sI-ADMM
    scheme: str = "uncoded"  # "uncoded" | "fractional" | "cyclic"
    exact_x: bool = False  # True => I-ADMM (closed-form x-update)
    traversal: str = "hamiltonian"  # or "shortest_path"
    seed: int = 0

    @property
    def M_bar(self) -> int:
        """Straggler-constrained batch size, eq. (22): M_bar = M/(S+1)."""
        return self.M // (self.S + 1)

    def validate(self) -> None:
        if self.M % ((self.S + 1) * self.K) != 0:
            raise ValueError(
                f"M={self.M} must be divisible by (S+1)*K="
                f"{(self.S + 1) * self.K}"
            )
        if self.scheme == "uncoded" and self.S != 0:
            raise ValueError("uncoded scheme cannot tolerate stragglers")


@dataclasses.dataclass
class Trace:
    """Per-iteration experiment record (all numpy, length = iters)."""

    accuracy: np.ndarray  # eq. (23) relative error
    test_error: np.ndarray  # MSE of the token z on the test set
    comm_cost: np.ndarray  # cumulative units (1 per token hop)
    sim_time: np.ndarray  # cumulative simulated seconds
    z_err: np.ndarray  # ||z - x*|| / ||x*||
    final_x: np.ndarray  # (N, p, d)
    final_z: np.ndarray  # (p, d)


def make_schedule(
    cfg: ADMMConfig,
    net: Network,
    code: GradientCode,
    straggler: StragglerModel,
    iters: int,
    b: int,
) -> dict:
    """Host-side per-iteration schedule: agents, batches, decode vectors, time.

    Returns dict of numpy arrays consumed by the jitted scan + the
    time/communication accounting.
    """
    rng = np.random.default_rng(cfg.seed)
    K, S = cfg.K, cfg.S
    P = b // K  # partition size per ECN slot
    mu = cfg.M_bar // K  # per-partition sub-batch size
    nb = max(P // mu, 1)  # batches per partition (paper step 16)

    # --- agent traversal -------------------------------------------------
    if cfg.traversal == "hamiltonian":
        route = np.array(net.hamiltonian, dtype=np.int32)
    elif cfg.traversal == "shortest_path":
        route = np.array(net.shortest_path_cycle, dtype=np.int32)
    else:
        raise ValueError(f"unknown traversal {cfg.traversal!r}")
    reps = int(np.ceil(iters / len(route)))
    agents = np.tile(route, reps)[:iters]

    # --- mini-batch index (Algorithm 1 step 16 / Algorithm 2 step 15) ----
    cycle = np.arange(iters) // net.N  # cycle index m
    offsets = ((cycle % nb) * mu).astype(np.int32)

    # --- stragglers & decoding (vectorized over iterations) --------------
    ecn_t, link_t = sample_times(straggler, iters, K, seed=cfg.seed + 1)
    if cfg.scheme == "uncoded":
        recv = ecn_t <= straggler.epsilon
        # nobody under the cap: wait for the fastest ECN
        none = ~recv.any(axis=1)
        recv[none, np.argmin(ecn_t[none], axis=1)] = True
        decode = recv * (K / recv.sum(axis=1, keepdims=True))
        resp = np.minimum(ecn_t.max(axis=1), straggler.epsilon)
    else:
        order = np.argsort(ecn_t, axis=1)
        alive = np.zeros((iters, K), dtype=bool)
        np.put_along_axis(alive, order[:, : code.R], True, axis=1)
        # Decode vectors depend only on the alive pattern, so solve the
        # lstsq once per distinct pattern — a sweep samples thousands of
        # iterations but only ever sees C(K, S) patterns.
        patterns, inverse = np.unique(alive, axis=0, return_inverse=True)
        vecs = np.stack([code.decode_vector(a) for a in patterns])
        decode = vecs[inverse]
        # response time = the R-th fastest ECN, capped at epsilon
        r_th = np.take_along_axis(ecn_t, order[:, code.R - 1 : code.R], axis=1)
        resp = np.minimum(r_th[:, 0], straggler.epsilon)

    tau = cfg.c_tau * np.sqrt(np.arange(1, iters + 1))
    gamma = cfg.c_gamma / np.sqrt(np.arange(1, iters + 1))

    return dict(
        agents=agents,
        offsets=offsets,
        decode=decode,
        tau=tau,
        gamma=gamma,
        resp_time=resp,
        link_time=link_t,
        mu=mu,
        P=P,
    )


def _scan_admm_impl(
    O: jax.Array,  # (N, b, p)
    T: jax.Array,  # (N, b, d)
    B: jax.Array,  # (K, K) encode matrix
    x_star: jax.Array,  # (p, d)
    O_test: jax.Array,
    T_test: jax.Array,
    agents: jax.Array,
    offsets: jax.Array,
    decode: jax.Array,
    tau: jax.Array,
    gamma: jax.Array,
    rho: jax.Array,  # scalar
    *,
    mu: int,
    P: int,
    K: int,
    N: int,
    exact_x: bool,
):
    p, d = O.shape[2], T.shape[2]
    x0 = jnp.zeros((N, p, d), O.dtype)
    y0 = jnp.zeros((N, p, d), O.dtype)
    z0 = jnp.zeros((p, d), O.dtype)
    xs_norm = jnp.linalg.norm(x_star)

    # Precomputed exact-solve operands (I-ADMM): (O^T O / b + rho I), O^T T / b
    H = jnp.einsum("nbp,nbq->npq", O, O) / O.shape[1]
    rhs0 = jnp.einsum("nbp,nbd->npd", O, T) / O.shape[1]
    eye = jnp.eye(p, dtype=O.dtype)

    def step(carry, inp):
        x, y, z = carry
        i, off, a, tk, gk = inp
        Oi = O[i]
        Ti = T[i]
        xi, yi = x[i], y[i]

        if exact_x:
            # I-ADMM exact x-update (eq. 4a) -- full-batch least squares.
            x_new = jnp.linalg.solve(
                H[i] + rho * eye, rhs0[i] + rho * z + yi
            )
        else:
            # Per-partition mini-batch gradients g~_t (Algorithms 1&2).
            def pgrad(t):
                Ob = jax.lax.dynamic_slice(Oi, (t * P + off, 0), (mu, p))
                Tb = jax.lax.dynamic_slice(Ti, (t * P + off, 0), (mu, d))
                return Ob.T @ (Ob @ xi - Tb) / mu

            gbar = jax.vmap(pgrad)(jnp.arange(K))  # (K, p, d)
            msgs = jnp.tensordot(B, gbar, axes=1)  # encode, (K, p, d)
            G = jnp.tensordot(a, msgs, axes=1) / K  # decode + eq. (6)
            # Proximal linearized x-update (eq. 5a).
            x_new = (tk * xi + rho * z + yi - G) / (rho + tk)

        y_new = yi + rho * gk * (z - x_new)  # eq. (5b)
        z_new = z + ((x_new - xi) - (y_new - yi) / rho) / N  # eq. (4c)
        x = x.at[i].set(x_new)
        y = y.at[i].set(y_new)

        acc = jnp.mean(
            jnp.linalg.norm(
                (x - x_star[None]).reshape(N, -1), axis=1
            )
            / jnp.maximum(xs_norm, 1e-12)
        )
        r = O_test @ z_new - T_test
        test_err = jnp.mean(jnp.sum(r * r, axis=-1))
        z_err = jnp.linalg.norm(z_new - x_star) / jnp.maximum(xs_norm, 1e-12)
        return (x, y, z_new), (acc, test_err, z_err)

    (x, y, z), (acc, test_err, z_err) = jax.lax.scan(
        step, (x0, y0, z0), (agents, offsets, decode, tau, gamma)
    )
    return x, z, acc, test_err, z_err


_scan_admm = partial(
    jax.jit, static_argnames=("mu", "P", "K", "N", "exact_x")
)(_scan_admm_impl)


def _scan_admm_masked_impl(
    O,  # (N, b, p)
    T,
    B,
    x_star,
    O_test,
    T_test,
    agents,
    offsets,
    decode,
    tau,
    gamma,
    rho,  # scalar
    mu,  # scalar int — RUNTIME input (serial path has it static)
    *,
    MU: int,  # static upper bound of mu across the batch
    P: int,
    K: int,
    N: int,
    exact_x: bool,
):
    """Per-run scan with a *traced* sub-batch size mu (DESIGN.md §7).

    The engine-side variant of :func:`_scan_admm_impl`: the per-partition
    mini-batch is a fixed-size MU-row gather with rows >= mu zero-masked
    (adding exact zeros to the gradient sums), so runs with different
    straggler tolerance S — hence different mu = M/((S+1)K) — share ONE
    compiled trace and batch into ONE vmapped dispatch. Test error uses
    the precomputed Gram/cross matrices of the test set (identical
    algebra to ``O_test @ z`` residuals, p x p per step instead of
    n_test x p), since the per-step test matmul dominates the serial
    scan's runtime (EXPERIMENTS.md §Perf).
    """
    p, d = O.shape[2], T.shape[2]
    b = O.shape[1]
    x0 = jnp.zeros((N, p, d), O.dtype)
    y0 = jnp.zeros((N, p, d), O.dtype)
    z0 = jnp.zeros((p, d), O.dtype)
    xs_norm = jnp.linalg.norm(x_star)
    n_test = O_test.shape[0]
    Gt = O_test.T @ O_test  # (p, p)
    Ct = O_test.T @ T_test  # (p, d)
    TTt = jnp.sum(T_test * T_test)
    rows = jnp.arange(MU)
    valid = (rows < mu).astype(O.dtype)  # (MU,)
    inv_mu = 1.0 / mu.astype(O.dtype)
    # Flat views: per-step mini-batches gather the K*MU needed rows
    # straight out of the (N*b, p) pool instead of first copying the
    # active agent's whole (b, p) block — the block copy dominates the
    # serial scan's step time.
    O_flat = O.reshape(N * b, p)
    T_flat = T.reshape(N * b, d)
    # Encode->decode collapses to per-partition weights: the decoded
    # mini-batch gradient (eq. 6) is
    #   G = (1/K) sum_j a_j sum_t B[j,t] g~_t = sum_t w_t g~_t,
    #   w = (a^T B) / K,
    # so the whole coded data path is ONE row-weighted gradient. Masked
    # rows (>= mu) get weight exactly 0, which also kills their clamped
    # out-of-bounds gathers. w is per-step data, computed in one matmul.
    W_steps = (decode @ B) / K  # (iters, K)
    part = jnp.arange(K)  # partition index per gather block

    if exact_x:
        H = jnp.einsum("nbp,nbq->npq", O, O) / O.shape[1]
        rhs0 = jnp.einsum("nbp,nbd->npd", O, T) / O.shape[1]
        eye = jnp.eye(p, dtype=O.dtype)

    def step(carry, inp):
        x, y, z = carry
        i, off, w, tk, gk = inp
        xi, yi = x[i], y[i]

        if exact_x:
            x_new = jnp.linalg.solve(
                H[i] + rho * eye, rhs0[i] + rho * z + yi
            )
        else:
            # One gather of all K partitions' sub-batches; OOB rows clamp
            # at the pool end and carry weight 0.
            idx = (i * b + part[:, None] * P + off + rows[None, :]).reshape(-1)
            Ob = O_flat[idx]  # (K*MU, p)
            Tb = T_flat[idx]  # (K*MU, d)
            c = ((w * inv_mu)[:, None] * valid[None, :]).reshape(-1, 1)
            G = Ob.T @ (c * (Ob @ xi - Tb))  # decoded eq. (6) gradient
            x_new = (tk * xi + rho * z + yi - G) / (rho + tk)

        y_new = yi + rho * gk * (z - x_new)  # eq. (5b)
        z_new = z + ((x_new - xi) - (y_new - yi) / rho) / N  # eq. (4c)
        x = x.at[i].set(x_new)
        y = y.at[i].set(y_new)

        acc = jnp.mean(
            jnp.linalg.norm(
                (x - x_star[None]).reshape(N, -1), axis=1
            )
            / jnp.maximum(xs_norm, 1e-12)
        )
        # ||O z - T||^2 / n = (z'Gz - 2<z, C> + ||T||^2) / n
        test_err = (
            jnp.einsum("pd,pq,qd->", z_new, Gt, z_new)
            - 2.0 * jnp.vdot(z_new, Ct)
            + TTt
        ) / n_test
        z_err = jnp.linalg.norm(z_new - x_star) / jnp.maximum(xs_norm, 1e-12)
        return (x, y, z_new), (acc, test_err, z_err)

    (x, y, z), (acc, test_err, z_err) = jax.lax.scan(
        step, (x0, y0, z0), (agents, offsets, W_steps, tau, gamma)
    )
    return x, z, acc, test_err, z_err


@partial(jax.jit, static_argnames=("MU", "P", "K", "N", "exact_x"))
def _scan_admm_batched(
    O,  # (R, N, b, p) — leading runs axis on every array argument
    T,
    B,
    x_star,
    O_test,
    T_test,
    agents,
    offsets,
    decode,
    tau,
    gamma,
    rho,  # (R,)
    mu,  # (R,)
    *,
    MU: int,
    P: int,
    K: int,
    N: int,
    exact_x: bool,
):
    """One compiled trace for a whole grid of runs (DESIGN.md §7).

    Every array input carries a leading runs axis R; the per-run masked
    scan is ``vmap``-ed over it, so R (seed, config) pairs — including
    runs with different S / mini-batch sizes — execute as a single
    vectorized ``lax.scan``.
    """
    f = partial(
        _scan_admm_masked_impl, MU=MU, P=P, K=K, N=N, exact_x=exact_x
    )
    return jax.vmap(f)(
        O, T, B, x_star, O_test, T_test,
        agents, offsets, decode, tau, gamma, rho, mu,
    )


def admm_static_signature(problem: LeastSquaresProblem, cfg: ADMMConfig) -> tuple:
    """Hashable key of everything that forces a fresh jit trace.

    Runs with equal signatures can be stacked on a leading axis and
    executed by a single `_scan_admm_batched` call (DESIGN.md §7). The
    sub-batch size mu is deliberately NOT part of the key — the batched
    scan takes it as a runtime input, so a whole S sweep (fig5) shares
    one trace.
    """
    P = problem.b // cfg.K
    return (
        "admm",
        problem.N, problem.b, problem.p, problem.d,
        problem.O_test.shape[0],
        cfg.K, P, cfg.exact_x,
    )


def _prepare_run(
    problem: LeastSquaresProblem,
    net: Network,
    cfg: ADMMConfig,
    iters: int,
    straggler: Optional[StragglerModel],
    code: Optional[GradientCode],
) -> dict:
    """Host-side per-run arrays + statics shared by serial/batched entry."""
    cfg.validate()
    straggler = straggler or StragglerModel()
    code = code or make_code(cfg.scheme, cfg.K, cfg.S, seed=cfg.seed)
    if code.K != cfg.K or code.S != cfg.S:
        raise ValueError("code does not match config (K, S)")

    sched = make_schedule(cfg, net, code, straggler, iters, problem.b)
    dt = problem.O.dtype
    x_star = problem.x_star()
    return dict(
        arrays=(
            problem.O,
            problem.T,
            code.B.astype(dt),
            x_star.astype(dt),
            problem.O_test,
            problem.T_test,
            sched["agents"],
            sched["offsets"],
            sched["decode"].astype(dt),
            sched["tau"].astype(dt),
            sched["gamma"].astype(dt),
            np.asarray(cfg.rho, dtype=dt),
        ),
        statics=dict(
            mu=sched["mu"], P=sched["P"], K=cfg.K, N=problem.N,
            exact_x=cfg.exact_x,
        ),
        # One token hop per activation; response + link time per iteration.
        comm=np.cumsum(np.ones(iters)),
        sim_time=np.cumsum(sched["resp_time"] + sched["link_time"]),
    )


def _to_trace(run: dict, x, z, acc, test_err, z_err) -> Trace:
    return Trace(
        accuracy=np.asarray(acc),
        test_error=np.asarray(test_err),
        comm_cost=run["comm"],
        sim_time=run["sim_time"],
        z_err=np.asarray(z_err),
        final_x=np.asarray(x),
        final_z=np.asarray(z),
    )


def run_incremental_admm(
    problem: LeastSquaresProblem,
    net: Network,
    cfg: ADMMConfig,
    iters: int,
    straggler: Optional[StragglerModel] = None,
    code: Optional[GradientCode] = None,
) -> Trace:
    """Run I-/sI-/csI-ADMM for ``iters`` activations and return the trace."""
    run = _prepare_run(problem, net, cfg, iters, straggler, code)
    out = _scan_admm(
        *(jnp.asarray(a) for a in run["arrays"]), **run["statics"]
    )
    return _to_trace(run, *out)


def run_incremental_admm_batch(
    problems: Sequence[LeastSquaresProblem],
    nets: Sequence[Network],
    cfgs: Sequence[ADMMConfig],
    iters: int,
    stragglers: Optional[Sequence[Optional[StragglerModel]]] = None,
    codes: Optional[Sequence[Optional[GradientCode]]] = None,
) -> List[Trace]:
    """Run R (problem, net, cfg) triples as ONE vmapped scan (DESIGN.md §7).

    All runs must share the same static signature
    (:func:`admm_static_signature`) — same shapes, K, mu, P, exact_x — so
    the whole batch costs a single jit trace and a single device dispatch.
    Per-run randomness (topology, data, straggler times, decode vectors)
    lives in the stacked array inputs. Raises ValueError on mixed statics;
    callers wanting heterogeneous grids should group first
    (`repro.experiments.sweep` does exactly that).
    """
    R = len(problems)
    if not (len(nets) == len(cfgs) == R):
        raise ValueError("problems, nets, cfgs must have equal length")
    stragglers = stragglers if stragglers is not None else [None] * R
    codes = codes if codes is not None else [None] * R

    sigs = {admm_static_signature(p, c) for p, c in zip(problems, cfgs)}
    if len(sigs) != 1:
        raise ValueError(
            f"batch mixes {len(sigs)} static signatures; group runs by "
            "admm_static_signature() first"
        )

    runs = [
        _prepare_run(p, n, c, iters, s, cd)
        for p, n, c, s, cd in zip(problems, nets, cfgs, stragglers, codes)
    ]
    stacked = tuple(
        jnp.asarray(np.stack([r["arrays"][i] for r in runs]))
        for i in range(len(runs[0]["arrays"]))
    )
    mus = np.asarray([r["statics"]["mu"] for r in runs])
    statics = {
        k: v for k, v in runs[0]["statics"].items() if k not in ("mu", "P")
    }
    out = _scan_admm_batched(
        *stacked, jnp.asarray(mus),
        MU=int(mus.max()), P=runs[0]["statics"]["P"], **statics,
    )
    out = [np.asarray(o) for o in out]
    return [
        _to_trace(run, *(o[r] for o in out)) for r, run in enumerate(runs)
    ]
