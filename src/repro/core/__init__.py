"""The paper's primary contribution: (coded) stochastic incremental ADMM.

Faithful implementation of Algorithms 1 & 2 plus the baselines and the
timing/straggler model used in the paper's experiments (§V). The distributed
TPU mapping of the same algorithm lives in `repro.distributed`.
"""

from .admm import ADMMConfig, Trace, run_incremental_admm
from .baselines import run_dadmm, run_dgd, run_extra, run_wadmm
from .coding import GradientCode, make_code, paper_fig2_code
from .graph import Network, make_network, metropolis_weights
from .problems import (
    DATASETS,
    Dataset,
    LeastSquaresProblem,
    allocate,
    make_ijcnn1_standin,
    make_synthetic,
    make_usps_standin,
)
from .timing import StragglerModel, TimingModel, sample_times

__all__ = [
    "ADMMConfig",
    "Trace",
    "run_incremental_admm",
    "run_dadmm",
    "run_dgd",
    "run_extra",
    "run_wadmm",
    "GradientCode",
    "make_code",
    "paper_fig2_code",
    "Network",
    "make_network",
    "metropolis_weights",
    "DATASETS",
    "Dataset",
    "LeastSquaresProblem",
    "allocate",
    "make_synthetic",
    "make_usps_standin",
    "make_ijcnn1_standin",
    "StragglerModel",
    "TimingModel",
    "sample_times",
]
