"""Decentralized consensus problems and datasets — paper §V (eq. 24, Table I).

The paper evaluates decentralized least squares

    f_i(x_i; D_i) = 1/(2 b_i) * sum_j || x_i^T o_{i,j} - t_{i,j} ||^2 ,

with x in R^{p x d}, on one synthetic and two real datasets (USPS, ijcnn1).
The container is offline, so the real sets are replaced by *shape-and-scale
matched* synthetic stand-ins (same #samples, p, d, and a planted linear
model + noise); the synthetic dataset follows the paper exactly
(x_o, o_i ~ N(0, I), t_i = x_o^T o_i + e_i). This substitution is recorded
in DESIGN.md §6 — every claim we validate (convergence rate, communication
cost, straggler robustness) depends on the least-squares structure, not on
the specific images.

Data layout mirrors Algorithms 1 & 2: dataset D_i of agent i is divided into
K equal disjoint partitions xi_{i,j} (one per ECN); ECN j slices mini-batches
of size M/K (uncoded) or (S+1)*Mbar/K (coded, over its (S+1) assigned
partitions) with the paper's cyclic batch index I_{i,j}^k = m mod floor(...).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "Dataset",
    "LeastSquaresProblem",
    "make_synthetic",
    "make_usps_standin",
    "make_ijcnn1_standin",
    "DATASETS",
]


@dataclasses.dataclass(frozen=True)
class Dataset:
    """A regression dataset: inputs O (n, p), targets T (n, d)."""

    name: str
    O_train: np.ndarray
    T_train: np.ndarray
    O_test: np.ndarray
    T_test: np.ndarray

    @property
    def p(self) -> int:
        return self.O_train.shape[1]

    @property
    def d(self) -> int:
        return self.T_train.shape[1]


def _planted(n_train: int, n_test: int, p: int, d: int, noise: float, seed: int, name: str) -> Dataset:
    rng = np.random.default_rng(seed)
    x_o = rng.standard_normal((p, d))
    O = rng.standard_normal((n_train + n_test, p))
    T = O @ x_o + noise * rng.standard_normal((n_train + n_test, d))
    return Dataset(
        name,
        O[:n_train],
        T[:n_train],
        O[n_train:],
        T[n_train:],
    )


def make_synthetic(seed: int = 0, noise: float = 0.1) -> Dataset:
    """Paper Table I synthetic: 50,400 train / 5,040 test, p=3, d=1."""
    return _planted(50_400, 5_040, 3, 1, noise, seed, "synthetic")


def make_usps_standin(seed: int = 1) -> Dataset:
    """USPS-shaped stand-in: 1,000 train / 100 test, p=64, d=10."""
    return _planted(1_000, 100, 64, 10, 0.3, seed, "usps")


def make_ijcnn1_standin(seed: int = 2) -> Dataset:
    """ijcnn1-shaped stand-in: 35,000 train / 3,500 test, p=22, d=2."""
    return _planted(35_000, 3_500, 22, 2, 0.2, seed, "ijcnn1")


DATASETS = {
    "synthetic": make_synthetic,
    "usps": make_usps_standin,
    "ijcnn1": make_ijcnn1_standin,
}


@dataclasses.dataclass(frozen=True)
class LeastSquaresProblem:
    """Consensus least squares over N agents (eq. 24).

    Arrays are stacked per agent with equal local sizes b (the paper allocates
    data "disjointly" across agents; we truncate to a multiple of N*K so all
    vectorized shapes are static).

      O: (N, b, p)   T: (N, b, d)
    """

    O: np.ndarray
    T: np.ndarray
    O_test: np.ndarray
    T_test: np.ndarray
    name: str = "lsq"

    @property
    def N(self) -> int:
        return self.O.shape[0]

    @property
    def b(self) -> int:
        return self.O.shape[1]

    @property
    def p(self) -> int:
        return self.O.shape[2]

    @property
    def d(self) -> int:
        return self.T.shape[2]

    # ---- oracles ---------------------------------------------------------

    def grad(self, i: int, x: np.ndarray, rows: Optional[np.ndarray] = None) -> np.ndarray:
        """(Stochastic) gradient of f_i at x using the given sample rows."""
        O = self.O[i] if rows is None else self.O[i][rows]
        T = self.T[i] if rows is None else self.T[i][rows]
        return O.T @ (O @ x - T) / O.shape[0]

    def loss(self, i: int, x: np.ndarray) -> float:
        r = self.O[i] @ x - self.T[i]
        return float(0.5 * np.sum(r * r) / self.b)

    def global_loss(self, xs: np.ndarray) -> float:
        """Sum_i f_i(x_i) with per-agent iterates xs (N, p, d)."""
        return float(sum(self.loss(i, xs[i]) for i in range(self.N)))

    def test_error(self, x: np.ndarray) -> float:
        """Mean-square test error of a single (consensus) model x (p, d)."""
        r = self.O_test @ x - self.T_test
        return float(np.mean(np.sum(r * r, axis=-1)))

    def x_star(self) -> np.ndarray:
        """Closed-form global optimum of sum_i f_i (eq. 1)."""
        p, d = self.p, self.d
        H = np.zeros((p, p))
        g = np.zeros((p, d))
        for i in range(self.N):
            H += self.O[i].T @ self.O[i] / self.b
            g += self.O[i].T @ self.T[i] / self.b
        return np.linalg.solve(H, g)

    def accuracy(self, xs: np.ndarray, x_star: np.ndarray, x_init: np.ndarray) -> float:
        """Relative error metric of eq. (23)."""
        num = np.linalg.norm(
            (xs - x_star[None]).reshape(self.N, -1), axis=1
        )
        den = np.linalg.norm(
            (x_init - x_star[None]).reshape(self.N, -1), axis=1
        )
        return float(np.mean(num / np.maximum(den, 1e-12)))


def allocate(dataset: Dataset, N: int, K: int = 1) -> LeastSquaresProblem:
    """Disjointly allocate a dataset across N agents (paper §V-A).

    Truncates to b = floor(n / N) samples per agent, with b further floored
    to a multiple of K so ECN partitions are equal-sized.
    """
    n = dataset.O_train.shape[0]
    b = (n // N // K) * K
    if b == 0:
        raise ValueError(f"dataset {dataset.name} too small for N={N}, K={K}")
    O = dataset.O_train[: N * b].reshape(N, b, dataset.p)
    T = dataset.T_train[: N * b].reshape(N, b, dataset.d)
    return LeastSquaresProblem(
        O, T, dataset.O_test, dataset.T_test, name=dataset.name
    )
