"""W-ADMM (Walkman [3]) as a MethodKernel — random-walk incremental ADMM.

Same incremental proximal-linearized updates as sI-ADMM, but the token
performs a uniform random walk over neighbors (one agent + one link per
iteration) and the stochastic gradient is a plain contiguous mini-batch
(no ECN partitioning / coding).

Simulated wall-clock: each walk step costs the active agent's compute
plus one link hop (`TimingModel.walk_step_times`, DESIGN.md §10) — no
redundancy, so a straggling agent blocks the token for its full delay.
Timing draws use the composite seed stream [5, seed], keeping the walk
itself (scalar-seeded) bit-identical to the pre-timing traces.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Network
from repro.core.problems import LeastSquaresProblem
from repro.core.timing import TimingModel

from .admm import ADMMRun
from .base import MethodKernel, Prepared, register

__all__ = ["WalkmanADMM", "W_ADMM"]


class WalkmanADMM(MethodKernel):
    name = "W-ADMM"

    def config(self, case) -> ADMMRun:
        return ADMMRun(case.admm_config(), case.timing_model())

    def static_signature(
        self, problem: LeastSquaresProblem, run: ADMMRun, iters: int
    ) -> tuple:
        return (
            self.name, run.cfg.M,
            problem.N, problem.b, problem.p, problem.d,
            problem.O_test.shape[0], iters,
        )

    def prepare(
        self,
        problem: LeastSquaresProblem,
        net: Network,
        run: ADMMRun,
        iters: int,
    ) -> Prepared:
        cfg = run.cfg
        timing = run.timing or TimingModel()
        if timing.is_async:
            # The walk's single token has no in-flight redundancy to
            # delay and no fleet to churn — a crashed holder would simply
            # end the run. Keep the failure loud rather than silently
            # running synchronously (DESIGN.md §13).
            raise NotImplementedError(
                "W-ADMM has no event-driven mode (tau_max/churn_rate must "
                "be 0); see DESIGN.md §13"
            )
        N, b = problem.N, problem.b
        rng = np.random.default_rng(cfg.seed)
        agents = np.zeros(iters, dtype=np.int32)
        cur = int(rng.integers(N))
        for k in range(iters):
            agents[k] = cur
            cur = int(rng.choice(net.neighbors(cur)))
        nb = max(b // cfg.M, 1)
        offsets = ((np.arange(iters) // N % nb) * cfg.M).astype(np.int32)
        tau = cfg.c_tau * np.sqrt(np.arange(1, iters + 1))
        gamma = cfg.c_gamma / np.sqrt(np.arange(1, iters + 1))
        dt = problem.O.dtype
        return Prepared(
            consts=(
                problem.O,
                problem.T,
                problem.x_star().astype(dt),
                problem.O_test,
                problem.T_test,
                np.asarray(cfg.rho, dtype=dt),
            ),
            steps=(agents, offsets, tau.astype(dt), gamma.astype(dt)),
            statics=dict(name=self.name, iters=iters, M=cfg.M, N=N),
            max_statics={},
            comm=np.cumsum(np.ones(iters)),  # one link per walk step
            sim_time=np.cumsum(
                (run.timing or TimingModel()).walk_step_times(
                    net, agents, np.random.default_rng([5, cfg.seed])
                )
            ),
        )

    def setup(self, consts, statics):
        O, T, x_star, O_test, T_test, rho = consts
        aux = self.lsq_aux(O, T, x_star, O_test, T_test)
        aux["rho"] = rho
        return aux

    def init(self, aux, statics):
        return self.xyz_state(aux)

    def step(self, state, inp, aux, statics):
        i, off, tk, gk = inp
        x, y, z = state["x"], state["y"], state["z"]
        rho, M, N = aux["rho"], statics["M"], statics["N"]
        p, d = aux["shape"][1], aux["shape"][2]
        zero = jnp.zeros((), off.dtype)
        Ob = jax.lax.dynamic_slice(aux["O"][i], (off, zero), (M, p))
        Tb = jax.lax.dynamic_slice(aux["T"][i], (off, zero), (M, d))
        xi, yi = x[i], y[i]
        G = Ob.T @ (Ob @ xi - Tb) / M
        x_new = (tk * xi + rho * z + yi - G) / (rho + tk)
        y_new = yi + rho * gk * (z - x_new)
        z_new = z + ((x_new - xi) - (y_new - yi) / rho) / N
        state = dict(
            x=x.at[i].set(x_new), y=y.at[i].set(y_new), z=z_new
        )
        return state, self.metrics(state["x"], z_new, aux)

    def final(self, state, aux, statics):
        return state["x"], state["z"]


W_ADMM = register(WalkmanADMM())
