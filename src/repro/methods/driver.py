"""Execution backends derived from a MethodKernel (DESIGN.md §8).

``run_serial`` executes one run as ``lax.scan(kernel.step)``;
``run_batch`` executes R runs as ``vmap`` of the *same* composed scan —
the batched engine is a pure performance transform of the serial path
because both call literally the same step function. The third backend,
the TPU mesh runtime (`repro.distributed.consensus`, DESIGN.md §3),
shares the algorithmic core but owns its sharding-aware state layout.

Jitted executables are cached per (kernel, statics) pair, on top of the
persistent XLA compilation cache enabled by `repro.experiments.sweep`.
"""

from __future__ import annotations

from functools import lru_cache
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.admm import Trace
from repro.core.graph import Network
from repro.core.problems import LeastSquaresProblem

from .base import MethodKernel, Prepared

__all__ = ["run_serial", "run_batch"]


def _statics_key(statics: dict) -> tuple:
    return tuple(sorted(statics.items()))


def _compose(kernel: MethodKernel, statics_key: tuple):
    """setup -> init -> scan(step) -> final as ONE pure run function."""
    statics = dict(statics_key)

    def run(consts, steps):
        aux = kernel.setup(consts, statics)
        state = kernel.init(aux, statics)

        def body(s, inp):
            return kernel.step(s, inp, aux, statics)

        xs = steps if steps else None
        length = None if steps else statics["iters"]
        state, metrics = jax.lax.scan(body, state, xs, length=length)
        x, z = kernel.final(state, aux, statics)
        return x, z, metrics

    return run


@lru_cache(maxsize=None)
def _serial_fn(kernel: MethodKernel, statics_key: tuple):
    return jax.jit(_compose(kernel, statics_key))


@lru_cache(maxsize=None)
def _batch_fn(kernel: MethodKernel, statics_key: tuple):
    return jax.jit(jax.vmap(_compose(kernel, statics_key)))


def _to_trace(prep: Prepared, x, z, metrics) -> Trace:
    acc, test_err, z_err = metrics
    return Trace(
        accuracy=np.asarray(acc),
        test_error=np.asarray(test_err),
        comm_cost=prep.comm,
        sim_time=prep.sim_time,
        z_err=np.asarray(z_err),
        final_x=np.asarray(x),
        final_z=np.asarray(z),
    )


def run_serial(
    kernel: MethodKernel,
    problem: LeastSquaresProblem,
    net: Network,
    cfg,
    iters: int,
) -> Trace:
    """One run: jitted ``lax.scan`` of the kernel's step function."""
    prep = kernel.prepare(problem, net, cfg, iters)
    statics = {**prep.statics, **prep.max_statics}
    fn = _serial_fn(kernel, _statics_key(statics))
    x, z, metrics = fn(
        tuple(jnp.asarray(c) for c in prep.consts),
        tuple(jnp.asarray(s) for s in prep.steps),
    )
    return _to_trace(prep, x, z, metrics)


def run_batch(
    kernel: MethodKernel,
    problems: Sequence[LeastSquaresProblem],
    nets: Sequence[Network],
    cfgs: Sequence,
    iters: int,
) -> List[Trace]:
    """R runs as ONE vmapped scan — one jit trace, one device dispatch.

    All runs must share the kernel's static signature; ``max_statics``
    (e.g. the masked gather bound MU) are reconciled with ``max`` so runs
    whose *runtime* value differs (mixed straggler tolerance S in a fig5
    grid) still share the trace. Raises ValueError on mixed statics —
    `repro.experiments.sweep.run_sweep` groups by signature first.
    """
    R = len(problems)
    if not (len(nets) == len(cfgs) == R):
        raise ValueError("problems, nets, cfgs must have equal length")
    sigs = {
        kernel.static_signature(p, c, iters)
        for p, c in zip(problems, cfgs)
    }
    if len(sigs) != 1:
        raise ValueError(
            f"batch mixes {len(sigs)} static signatures; group runs by "
            f"{kernel.name} static_signature() first"
        )

    preps = [
        kernel.prepare(p, n, c, iters)
        for p, n, c in zip(problems, nets, cfgs)
    ]
    statics = dict(preps[0].statics)
    if any(pr.statics != statics for pr in preps[1:]):
        raise ValueError("equal signatures produced unequal statics")
    for key in preps[0].max_statics:
        statics[key] = max(pr.max_statics[key] for pr in preps)

    consts = tuple(
        jnp.asarray(np.stack([np.asarray(pr.consts[i]) for pr in preps]))
        for i in range(len(preps[0].consts))
    )
    steps = tuple(
        jnp.asarray(np.stack([np.asarray(pr.steps[i]) for pr in preps]))
        for i in range(len(preps[0].steps))
    )
    fn = _batch_fn(kernel, _statics_key(statics))
    x, z, (acc, test_err, z_err) = fn(consts, steps)
    out = [np.asarray(o) for o in (x, z, acc, test_err, z_err)]
    return [
        _to_trace(pr, out[0][r], out[1][r], (out[2][r], out[3][r], out[4][r]))
        for r, pr in enumerate(preps)
    ]
