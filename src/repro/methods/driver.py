"""Execution backends derived from a MethodKernel (DESIGN.md §8, §9).

``run_serial`` executes one run as ``lax.scan(kernel.step)``;
``run_batch`` executes R runs as ``vmap`` of the *same* composed scan —
the batched engine is a pure performance transform of the serial path
because both call literally the same step function. ``run_sharded`` lays
the batched runs axis of that same vmapped scan out over a
`jax.sharding.Mesh` of every visible device (``shard_map`` over a 1-D
runs mesh, NamedSharding-placed inputs, buffer donation on accelerator
backends, automatic chunking when a grid exceeds the per-device memory
budget), falling back structurally to the single-device vmap when only
one device is visible (DESIGN.md §9). A fourth backend, the TPU mesh runtime
(`repro.distributed.consensus`, DESIGN.md §3), shares the algorithmic
core but owns its sharding-aware state layout.

Jitted executables are cached per (kernel, statics) pair, on top of the
persistent XLA compilation cache enabled by `repro.experiments.sweep`.
"""

from __future__ import annotations

import os
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.admm import Trace
from repro.core.graph import Network
from repro.core.problems import LeastSquaresProblem
from repro.distributed.sharding import AxisLayout, batch_specs

from .base import MethodKernel, Prepared
from .reductions import Reduction

__all__ = ["run_serial", "run_batch", "run_sharded"]


def _statics_key(statics: dict) -> tuple:
    return tuple(sorted(statics.items()))


def _compose(kernel: MethodKernel, statics_key: tuple):
    """setup -> init -> scan(step) -> final as ONE pure run function."""
    statics = dict(statics_key)

    def run(consts, steps):
        aux = kernel.setup(consts, statics)
        state = kernel.init(aux, statics)

        def body(s, inp):
            return kernel.step(s, inp, aux, statics)

        xs = steps if steps else None
        length = None if steps else statics["iters"]
        state, metrics = jax.lax.scan(body, state, xs, length=length)
        x, z = kernel.final(state, aux, statics)
        return x, z, metrics

    return run


def _compose_reduced(
    kernel: MethodKernel, statics_key: tuple, spec: Reduction
):
    """setup -> init -> scan(step + reduction fold) -> finalize (§12).

    Same step function as `_compose`, but the per-iteration metrics feed
    a fixed-size `Reduction` carry instead of being stacked as scan
    outputs, and the cumulative sim_time/comm_cost clock rides along as
    the LAST per-step input (increments appended by `_clock_steps`, so
    kernels' positional ``inp`` indices are untouched by the ``[:-1]``
    slice). Output is the flat summary dict — O(spec), not O(iters).
    """
    statics = dict(statics_key)

    def run(consts, steps):
        aux = kernel.setup(consts, statics)
        state = kernel.init(aux, statics)
        red0 = spec.init_carry(steps[-1].dtype)

        def body(carry, inp):
            s, red = carry
            s, metrics = kernel.step(s, inp[:-1], aux, statics)
            return (s, spec.update_carry(red, metrics, inp[-1])), None

        (state, red), _ = jax.lax.scan(body, (state, red0), steps)
        out = spec.finalize_carry(red)
        if spec.final_x:
            out["final_x"], out["final_z"] = kernel.final(
                state, aux, statics
            )
        return out

    return run


def _clock_steps(prep: Prepared) -> np.ndarray:
    """(iters, 2) per-step [d_sim_time, d_comm] increments of the host
    clocks, ordered as `repro.methods.reductions.CLOCK_AXES`."""
    return np.stack(
        [
            np.diff(prep.sim_time, prepend=0.0),
            np.diff(np.asarray(prep.comm, dtype=np.float64), prepend=0.0),
        ],
        axis=1,
    )


@lru_cache(maxsize=None)
def _serial_fn(kernel: MethodKernel, statics_key: tuple):
    return jax.jit(_compose(kernel, statics_key))


@lru_cache(maxsize=None)
def _batch_fn(kernel: MethodKernel, statics_key: tuple):
    return jax.jit(jax.vmap(_compose(kernel, statics_key)))


@lru_cache(maxsize=None)
def _serial_reduced_fn(
    kernel: MethodKernel, statics_key: tuple, spec: Reduction
):
    return jax.jit(_compose_reduced(kernel, statics_key, spec))


@lru_cache(maxsize=None)
def _batch_reduced_fn(
    kernel: MethodKernel, statics_key: tuple, spec: Reduction
):
    return jax.jit(jax.vmap(_compose_reduced(kernel, statics_key, spec)))


def _to_trace(prep: Prepared, x, z, metrics) -> Trace:
    acc, test_err, z_err = metrics
    return Trace(
        accuracy=np.asarray(acc),
        test_error=np.asarray(test_err),
        comm_cost=prep.comm,
        sim_time=prep.sim_time,
        z_err=np.asarray(z_err),
        final_x=np.asarray(x),
        final_z=np.asarray(z),
    )


def run_serial(
    kernel: MethodKernel,
    problem: LeastSquaresProblem,
    net: Network,
    cfg,
    iters: int,
    reductions: Optional[Reduction] = None,
):
    """One run: jitted ``lax.scan`` of the kernel's step function.

    Returns a full `Trace`, or — with ``reductions`` — the run's flat
    summary dict of numpy arrays (DESIGN.md §12).
    """
    prep = kernel.prepare(problem, net, cfg, iters)
    statics = {**prep.statics, **prep.max_statics}
    consts = tuple(jnp.asarray(c) for c in prep.consts)
    if reductions is not None:
        fn = _serial_reduced_fn(kernel, _statics_key(statics), reductions)
        steps = tuple(jnp.asarray(s) for s in prep.steps) + (
            jnp.asarray(_clock_steps(prep)),
        )
        return {k: np.asarray(v) for k, v in fn(consts, steps).items()}
    fn = _serial_fn(kernel, _statics_key(statics))
    x, z, metrics = fn(
        consts, tuple(jnp.asarray(s) for s in prep.steps)
    )
    return _to_trace(prep, x, z, metrics)


def _stack_batch(
    kernel: MethodKernel,
    problems: Sequence[LeastSquaresProblem],
    nets: Sequence[Network],
    cfgs: Sequence,
    iters: int,
) -> Tuple[List[Prepared], dict, Tuple[np.ndarray, ...], Tuple[np.ndarray, ...]]:
    """Prepare R runs and stack them on a leading runs axis (host-side).

    All runs must share the kernel's static signature; ``max_statics``
    (e.g. the masked gather bound MU) are reconciled with ``max`` so runs
    whose *runtime* value differs (mixed straggler tolerance S in a fig5
    grid) still share the trace. Raises ValueError on mixed statics —
    `repro.experiments.sweep.run_sweep` groups by signature first.
    """
    R = len(problems)
    if not (len(nets) == len(cfgs) == R):
        raise ValueError("problems, nets, cfgs must have equal length")
    sigs = {
        kernel.static_signature(p, c, iters)
        for p, c in zip(problems, cfgs)
    }
    if len(sigs) != 1:
        raise ValueError(
            f"batch mixes {len(sigs)} static signatures; group runs by "
            f"{kernel.name} static_signature() first"
        )

    preps = [
        kernel.prepare(p, n, c, iters)
        for p, n, c in zip(problems, nets, cfgs)
    ]
    statics = dict(preps[0].statics)
    if any(pr.statics != statics for pr in preps[1:]):
        raise ValueError("equal signatures produced unequal statics")
    for key in preps[0].max_statics:
        statics[key] = max(pr.max_statics[key] for pr in preps)

    consts = tuple(
        np.stack([np.asarray(pr.consts[i]) for pr in preps])
        for i in range(len(preps[0].consts))
    )
    steps = tuple(
        np.stack([np.asarray(pr.steps[i]) for pr in preps])
        for i in range(len(preps[0].steps))
    )
    return preps, statics, consts, steps


def _unstack_traces(preps: List[Prepared], x, z, metrics) -> List[Trace]:
    acc, test_err, z_err = metrics
    out = [np.asarray(o) for o in (x, z, acc, test_err, z_err)]
    return [
        _to_trace(pr, out[0][r], out[1][r], (out[2][r], out[3][r], out[4][r]))
        for r, pr in enumerate(preps)
    ]


def run_batch(
    kernel: MethodKernel,
    problems: Sequence[LeastSquaresProblem],
    nets: Sequence[Network],
    cfgs: Sequence,
    iters: int,
    reductions: Optional[Reduction] = None,
):
    """R runs as ONE vmapped scan — one jit trace, one device dispatch.

    Returns per-run `Trace`s, or — with ``reductions`` — one dict of
    numpy arrays with a leading runs axis (DESIGN.md §12).
    """
    preps, statics, consts, steps = _stack_batch(
        kernel, problems, nets, cfgs, iters
    )
    if reductions is not None:
        fn = _batch_reduced_fn(kernel, _statics_key(statics), reductions)
        out = fn(
            tuple(jnp.asarray(c) for c in consts),
            tuple(jnp.asarray(s) for s in steps)
            + (jnp.asarray(np.stack([_clock_steps(p) for p in preps])),),
        )
        return {k: np.asarray(v) for k, v in out.items()}
    fn = _batch_fn(kernel, _statics_key(statics))
    x, z, metrics = fn(
        tuple(jnp.asarray(c) for c in consts),
        tuple(jnp.asarray(s) for s in steps),
    )
    return _unstack_traces(preps, x, z, metrics)


# --------------------------------------------------------------------------
# Mesh-sharded batch execution (DESIGN.md §9)
# --------------------------------------------------------------------------

# Per-device working-set budget for one sharded dispatch, in MiB. The
# chunking rule is deliberately coarse (inputs + outputs + one 2x slack
# factor for XLA temporaries); it only needs to keep a huge grid from
# OOMing a device, not to model the allocator.
_MEM_BUDGET_ENV = "REPRO_SHARD_MEM_MB"
_DEFAULT_MEM_MB = 4096


def _runs_mesh() -> Mesh:
    """1-D device mesh over the runs axis (trailing size-1 model axis so
    `repro.distributed.sharding.AxisLayout` spec inference applies)."""
    devs = np.array(jax.devices()).reshape(-1, 1)
    return Mesh(devs, ("runs", "model"))


@lru_cache(maxsize=None)
def _sharded_fn(
    kernel: MethodKernel,
    statics_key: tuple,
    D: int,
    n_consts: int,
    n_steps: int,
    donate: bool,
):
    """jit(shard_map(vmap(compose))) over the runs axis of a 1-D mesh.

    shard_map (not bare NamedSharding propagation) because the step's
    Pallas `coded_admm_update` has no SPMD partitioning rule: under
    GSPMD, XLA walls the op off and reshards its operands every scan
    iteration (measured ~50x slower); under shard_map each device runs
    the whole vmapped scan on its local R/D runs and the Pallas call
    never sees a partitioned operand. check_rep=False for the same
    reason (pallas_call has no replication rule). Nothing in the scan
    crosses the runs axis, so per-run math — and the outputs — are
    bitwise identical to the single-device vmap.
    """
    mesh = _runs_mesh()
    assert mesh.devices.shape[0] == D  # cache key consistency
    spec = (
        tuple(P("runs") for _ in range(n_consts)),
        tuple(P("runs") for _ in range(n_steps)),
    )
    out_spec = (P("runs"), P("runs"), (P("runs"), P("runs"), P("runs")))
    fn = shard_map(
        jax.vmap(_compose(kernel, statics_key)),
        mesh=mesh,
        in_specs=spec,
        out_specs=out_spec,
        check_rep=False,
    )
    return jax.jit(fn, donate_argnums=(0, 1) if donate else ())


@lru_cache(maxsize=None)
def _sharded_reduced_fn(
    kernel: MethodKernel,
    statics_key: tuple,
    spec: Reduction,
    D: int,
    n_consts: int,
    n_steps: int,
    donate: bool,
):
    """jit(shard_map(vmap(compose_reduced))) — the streaming sharded tier.

    Same mesh/shard_map rationale as `_sharded_fn`; the single bare
    ``P("runs")`` out_spec applies as a prefix to every leaf of the
    summary dict (each leaf has a leading vmapped runs axis)."""
    mesh = _runs_mesh()
    assert mesh.devices.shape[0] == D
    in_spec = (
        tuple(P("runs") for _ in range(n_consts)),
        tuple(P("runs") for _ in range(n_steps + 1)),  # +1: clock steps
    )
    fn = shard_map(
        jax.vmap(_compose_reduced(kernel, statics_key, spec)),
        mesh=mesh,
        in_specs=in_spec,
        out_specs=P("runs"),
        check_rep=False,
    )
    return jax.jit(fn, donate_argnums=(0, 1) if donate else ())


def _bytes_per_run(
    consts, steps, statics: dict, preps: List[Prepared]
) -> int:
    """Estimated per-run device footprint: stacked inputs + scan outputs."""
    R = len(preps)
    in_bytes = sum(a.nbytes for a in consts + steps) // max(R, 1)
    iters = int(statics.get("iters", 1))
    # x/z outputs mirror the largest const (the data block); metrics are
    # 3 float traces of length iters.
    out_bytes = 3 * iters * 8
    for a in consts:
        out_bytes += a.nbytes // max(R, 1)
    return max(in_bytes + out_bytes, 1)


def _chunk_runs(R_pad: int, D: int, per_run_bytes: int) -> int:
    """Largest run count per dispatch within the per-device budget,
    a multiple of the device count D (so every chunk shards evenly)."""
    budget = int(os.environ.get(_MEM_BUDGET_ENV, _DEFAULT_MEM_MB)) * 2**20
    fit = (budget * D) // (2 * per_run_bytes)  # 2x slack for temporaries
    chunk = max(D, (fit // D) * D)
    return min(chunk, R_pad)


def _run_reduced_chunked(
    kernel: MethodKernel,
    problems: Sequence[LeastSquaresProblem],
    nets: Sequence[Network],
    cfgs: Sequence,
    iters: int,
    spec: Reduction,
) -> Dict[str, np.ndarray]:
    """Streaming sharded execution with LAZY per-chunk prepare (§12).

    The eager path prepares and stacks all R runs before dispatching —
    host memory O(R x iters) even though the device outputs are O(R).
    Here runs are prepared only when their chunk dispatches, so peak host
    memory is O(chunk x iters) + O(R x spec): the chunk size shrinks as
    per-run schedules grow (`_chunk_runs` on the prepared bytes of run
    0), which is what keeps fleet-scale RSS flat in ``iters``
    (EXPERIMENTS.md 'Fleet scale'). Requires the kernel's
    `max_statics_bound` to be exact enough that every chunk reconciles
    under ONE set of jit statics — one trace, one executable, chunk
    count dispatches.
    """
    D = len(jax.devices())
    sigs = {
        kernel.static_signature(p, c, iters)
        for p, c in zip(problems, cfgs)
    }
    if len(sigs) != 1:
        raise ValueError(
            f"batch mixes {len(sigs)} static signatures; group runs by "
            f"{kernel.name} static_signature() first"
        )
    bound: Dict[str, int] = {}
    for p, c in zip(problems, cfgs):
        for key, val in kernel.max_statics_bound(p, c, iters).items():
            bound[key] = max(bound.get(key, 0), int(val))

    # One probe prepare: fixes the shared statics and sizes the chunks.
    prep0 = kernel.prepare(problems[0], nets[0], cfgs[0], iters)
    if set(prep0.max_statics) != set(bound):
        raise ValueError(
            f"{kernel.name}.max_statics_bound() keys {sorted(bound)} != "
            f"prepared max_statics keys {sorted(prep0.max_statics)}; "
            "implement the bound hook for chunked streaming execution"
        )
    statics = {**prep0.statics, **bound}
    per_run = (
        sum(np.asarray(a).nbytes for a in prep0.consts + prep0.steps)
        + _clock_steps(prep0).nbytes
    )
    R = len(problems)
    mesh = _runs_mesh()
    layout = AxisLayout(mesh, data=("runs",), model="model")
    donate = jax.default_backend() in ("tpu", "gpu")
    del prep0  # the probe's schedules are re-prepared with its chunk

    chunk = _chunk_runs(-(-R // D) * D, D, max(per_run, 1))
    fn = None
    outs: List[Dict[str, np.ndarray]] = []
    for lo in range(0, R, chunk):
        hi = min(lo + chunk, R)
        preps = [
            kernel.prepare(p, n, c, iters)
            for p, n, c in zip(
                problems[lo:hi], nets[lo:hi], cfgs[lo:hi]
            )
        ]
        for pr in preps:
            if pr.statics != _shared_statics(statics, pr):
                raise ValueError(
                    "equal signatures produced unequal statics"
                )
            for key, val in pr.max_statics.items():
                if int(val) > statics[key]:
                    raise ValueError(
                        f"{kernel.name}.max_statics_bound() under-bounds "
                        f"{key}: prepared {val} > bound {statics[key]}"
                    )
        n = hi - lo
        csl = tuple(
            np.stack([np.asarray(pr.consts[i]) for pr in preps])
            for i in range(len(preps[0].consts))
        )
        ssl = tuple(
            np.stack([np.asarray(pr.steps[i]) for pr in preps])
            for i in range(len(preps[0].steps))
        ) + (np.stack([_clock_steps(pr) for pr in preps]),)
        del preps
        if fn is None:
            fn = _sharded_reduced_fn(
                kernel, _statics_key(statics), spec, D,
                len(csl), len(ssl) - 1, donate,
            )
        pad = -(-n // D) * D - n
        if pad:  # repeat the last run; its outputs are sliced off below
            csl = tuple(
                np.concatenate([a, np.repeat(a[-1:], pad, axis=0)])
                for a in csl
            )
            ssl = tuple(
                np.concatenate([a, np.repeat(a[-1:], pad, axis=0)])
                for a in ssl
            )
        cspec, sspec = batch_specs((csl, ssl), layout)
        put_c = tuple(
            jax.device_put(a, NamedSharding(mesh, s))
            for a, s in zip(csl, cspec)
        )
        put_s = tuple(
            jax.device_put(a, NamedSharding(mesh, s))
            for a, s in zip(ssl, sspec)
        )
        del csl, ssl  # the chunk's host copies die before the next one
        out = fn(put_c, put_s)
        outs.append({k: np.asarray(v)[:n] for k, v in out.items()})
    return {
        k: np.concatenate([o[k] for o in outs]) for k in outs[0]
    }


def _shared_statics(statics: dict, prep: Prepared) -> dict:
    """The statics a chunked run must agree on: everything but the
    max-reconciled keys (whose runtime values legitimately differ)."""
    return {
        k: v for k, v in statics.items() if k not in prep.max_statics
    }


def run_sharded(
    kernel: MethodKernel,
    problems: Sequence[LeastSquaresProblem],
    nets: Sequence[Network],
    cfgs: Sequence,
    iters: int,
    reductions: Optional[Reduction] = None,
):
    """R runs vmapped AND laid out over a device mesh on the runs axis.

    The computation is literally `run_batch`'s vmapped scan, wrapped in
    `shard_map` over a 1-D `Mesh` of all visible devices: each device
    executes the scan on its local R/D runs (see `_sharded_fn` for why
    shard_map rather than GSPMD propagation). Inputs are pre-placed with
    `NamedSharding`s inferred by `repro.distributed.sharding.batch_specs`
    so entry into the jitted shard_map moves no data. R is padded to a
    device-count multiple by repeating the last run (padded outputs are
    dropped), grids above the `REPRO_SHARD_MEM_MB` per-device budget are
    split into device-aligned chunks, and input buffers are donated on
    accelerator backends (XLA does not implement donation on CPU).
    Bitwise equal to `run_batch` because no op crosses the runs axis;
    with a single visible device it degrades to exactly `run_batch`.

    With ``reductions`` set, execution routes to `_run_reduced_chunked`:
    the same mesh layout, but runs are prepared lazily per chunk and the
    scan emits fixed-size streaming summaries instead of a full `Trace`
    (DESIGN.md §12) — the return value is one dict of (R, ...) numpy
    arrays. The bitwise claim above is for the Trace path; the in-scan
    fold fuses with the kernel math, so streaming summaries agree with
    `run_batch` to last-ulp tolerance rather than bit-for-bit (XLA
    fusion choices move with the per-device vmap batch size).
    """
    D = len(jax.devices())
    if D == 1 or len(problems) == 1:
        # Structural fallback: one device means nothing to lay out; one
        # run means padding would make every device compute a duplicate
        # of the same scan for no wall-clock gain.
        return run_batch(
            kernel, problems, nets, cfgs, iters, reductions=reductions
        )
    if reductions is not None:
        return _run_reduced_chunked(
            kernel, problems, nets, cfgs, iters, reductions
        )

    preps, statics, consts, steps = _stack_batch(
        kernel, problems, nets, cfgs, iters
    )
    R = len(preps)
    mesh = _runs_mesh()
    layout = AxisLayout(mesh, data=("runs",), model="model")
    donate = jax.default_backend() in ("tpu", "gpu")
    fn = _sharded_fn(
        kernel, _statics_key(statics), D, len(consts), len(steps), donate
    )

    chunk = _chunk_runs(
        -(-R // D) * D, D, _bytes_per_run(consts, steps, statics, preps)
    )
    outs: List[Tuple] = []
    for lo in range(0, R, chunk):
        n = min(chunk, R - lo)
        csl = tuple(a[lo : lo + n] for a in consts)
        ssl = tuple(a[lo : lo + n] for a in steps)
        pad = -(-n // D) * D - n
        if pad:  # repeat the last run; its outputs are sliced off below
            csl = tuple(
                np.concatenate([a, np.repeat(a[-1:], pad, axis=0)])
                for a in csl
            )
            ssl = tuple(
                np.concatenate([a, np.repeat(a[-1:], pad, axis=0)])
                for a in ssl
            )
        # PartitionSpec is tuple-like, so zip over the inferred specs
        # rather than tree-mapping across them.
        cspec, sspec = batch_specs((csl, ssl), layout)
        put_c = tuple(
            jax.device_put(a, NamedSharding(mesh, s))
            for a, s in zip(csl, cspec)
        )
        put_s = tuple(
            jax.device_put(a, NamedSharding(mesh, s))
            for a, s in zip(ssl, sspec)
        )
        x, z, (acc, te, ze) = fn(put_c, put_s)
        outs.append(
            tuple(np.asarray(o)[:n] for o in (x, z, acc, te, ze))
        )
    cat = [np.concatenate([o[i] for o in outs]) for i in range(5)]
    return _unstack_traces(preps, cat[0], cat[1], (cat[2], cat[3], cat[4]))
