"""Method kernels: one pure step function per algorithm (DESIGN.md §8).

Each consensus optimization method is a `MethodKernel` — host-side
``prepare`` plus pure ``setup``/``init``/``step``/``final`` — and every
execution backend is derived from it by `repro.methods.driver`:
``run_serial`` (one jitted ``lax.scan`` per run), ``run_batch`` (``vmap``
of the same scan over a leading runs axis), and ``run_sharded`` (the
same vmapped scan laid out over a device mesh on the runs axis,
DESIGN.md §9). Importing this package populates the `KERNELS` registry:

  sI-ADMM / csI-ADMM / I-ADMM  (paper Algorithms 1 & 2, eq. 4)
  W-ADMM, D-ADMM, DGD, EXTRA   (paper §V-A baselines)
  pI-ADMM                      (privacy-perturbed, arXiv 2003.10615)
  cq-sI-ADMM                   (compressed token, arXiv 2501.13516)
  a-csI-ADMM                   (bandit-controlled frontier, DESIGN.md §15)
"""

from .admm import ADMMRun, IncrementalADMM
from .base import KERNELS, MethodKernel, Prepared, get_kernel, register
from .compression import CompressionRun
from .driver import run_batch, run_serial, run_sharded
from .gossip import DADMM, DGD, EXTRA, GossipRun
from .privacy import PrivacyRun
from .reductions import METRIC_FIELDS, Reduction, reduce_trace
from .walkman import WalkmanADMM

# The adaptive controller kernel lives in `repro.control` (it layers ON
# TOP of the ADMM family) but registers through the same kernel table;
# a plain module import — last, so `repro.methods.admm` is complete, and
# attribute-free, so a controller-first import order can't deadlock the
# partially-initialized package.
import repro.control.kernel  # noqa: E402,F401

__all__ = [
    "MethodKernel",
    "Prepared",
    "KERNELS",
    "register",
    "get_kernel",
    "run_serial",
    "run_batch",
    "run_sharded",
    "Reduction",
    "reduce_trace",
    "METRIC_FIELDS",
    "ADMMRun",
    "GossipRun",
    "PrivacyRun",
    "CompressionRun",
    "IncrementalADMM",
    "WalkmanADMM",
    "DADMM",
    "DGD",
    "EXTRA",
]
