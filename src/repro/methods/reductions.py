"""Streaming in-scan reductions: O(grid) sweep memory (DESIGN.md §12).

A full `repro.core.admm.Trace` materializes every per-iteration metric —
memory O(iters x runs) — which caps sweep grids at tens of runs. The
paper's claims, however, are *statistical*: accuracy at a time budget,
time to reach an accuracy target, quantiles over straggler realizations.
A `Reduction` declares exactly those summaries, and the drivers fold
them into the ``lax.scan`` carry so a run's footprint is a fixed-size
pytree regardless of ``iters``:

- **running mean/M2** (Welford) of each metric over iterations — the
  trajectory average plus the variance the CI math needs;
- **running min** and **final value** of each metric;
- **accuracy-at-budget**: per-run budget-crossing detection against the
  cumulative ``sim_time``/``comm_cost`` clock carried through the scan
  (the same right-continuous step semantics as
  `repro.experiments.results.resample_runs`);
- **time-to-target**: first cumulative clock value at which the metric
  reaches each target (+inf when never);
- **streaming quantiles**: a fixed-bin histogram sketch as scan state,
  collapsed to quantile estimates at ``finalize``.

Everything is computed in-jit with no host round-trips; the only outputs
that leave the device are the fixed-size summaries. `reduce_trace` is
the numpy post-hoc reference — applying it to a materialized `Trace`
must match the in-scan fold to <= 1e-5 (property-tested in
``tests/test_reductions_properties.py``), which is what licenses the
fleet-scale sweeps to drop the Trace entirely.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["Reduction", "METRIC_FIELDS", "CLOCK_AXES", "reduce_trace"]

# Per-step metric tuple emitted by every MethodKernel.step, in order.
METRIC_FIELDS = ("accuracy", "test_error", "z_err")
# Cumulative clocks carried through the scan: index into the (2,) carry.
CLOCK_AXES = ("sim_time", "comm_cost")


@dataclasses.dataclass(frozen=True)
class Reduction:
    """Declarative spec of the in-scan summaries (hashable: jit cache key).

    Attributes:
      fields: metric fields to reduce (subset of `METRIC_FIELDS`). Every
        field always gets final/mean/var/min summaries.
      budgets: cumulative-``x`` budgets; each field additionally reports
        its value at the last iteration completed within each budget
        (held at the first recorded value when no iteration completes —
        the `resample_runs` step-function convention).
      x: the budget/time axis — "sim_time" or "comm_cost".
      targets: metric thresholds; each field additionally reports the
        first cumulative ``x`` at which it reached each target (+inf
        when never — `time_to_accuracy` for field="accuracy").
      quantiles: quantile levels in (0, 1]; estimated from a fixed-bin
        histogram of the metric over iterations (``bins`` bins spanning
        [lo, hi], out-of-range values clipped into the edge bins).
      bins, lo, hi: the histogram sketch geometry.
      final_x: also return the per-run final iterates (N, p, d)/(p, d)
        — O(model) per run, off by default.
    """

    fields: Tuple[str, ...] = ("accuracy",)
    budgets: Tuple[float, ...] = ()
    x: str = "sim_time"
    targets: Tuple[float, ...] = ()
    quantiles: Tuple[float, ...] = ()
    bins: int = 64
    lo: float = 0.0
    hi: float = 1.5
    final_x: bool = False

    def __post_init__(self) -> None:
        unknown = set(self.fields) - set(METRIC_FIELDS)
        if not self.fields or unknown:
            raise ValueError(
                f"fields must be a non-empty subset of {METRIC_FIELDS}, "
                f"got {self.fields}"
            )
        if self.x not in CLOCK_AXES:
            raise ValueError(
                f"unknown reduction axis {self.x!r}; known: {CLOCK_AXES}"
            )
        if any(b <= 0 for b in self.budgets):
            raise ValueError(f"budgets must be positive, got {self.budgets}")
        if any(not 0.0 < q <= 1.0 for q in self.quantiles):
            raise ValueError(
                f"quantiles must lie in (0, 1], got {self.quantiles}"
            )
        if self.quantiles and (self.bins < 1 or self.hi <= self.lo):
            raise ValueError(
                f"histogram sketch needs bins >= 1 and hi > lo, got "
                f"bins={self.bins}, [{self.lo}, {self.hi})"
            )

    @property
    def axis_index(self) -> int:
        return CLOCK_AXES.index(self.x)

    def keys(self) -> Tuple[str, ...]:
        """Output keys, in emission order (clock finals, then per-field)."""
        out = [f"{ax}/final" for ax in CLOCK_AXES]
        for f in self.fields:
            out += [f"{f}/final", f"{f}/mean", f"{f}/var", f"{f}/min"]
            if self.budgets:
                out.append(f"{f}/at_budget")
            if self.targets:
                out.append(f"{f}/time_to")
            if self.quantiles:
                out.append(f"{f}/quantiles")
        if self.final_x:
            out += ["final_x", "final_z"]
        return tuple(out)

    # -- in-scan fold (pure jax, called from the driver's scan body) -------

    def init_carry(self, dtype) -> dict:
        """Fixed-size reduction carry: O(budgets+targets+bins), not O(iters)."""
        carry = {
            "k": jnp.zeros((), jnp.int32),
            "clock": jnp.zeros((len(CLOCK_AXES),), dtype),
        }
        for f in self.fields:
            st = {
                "last": jnp.zeros((), dtype),
                "mean": jnp.zeros((), dtype),
                "m2": jnp.zeros((), dtype),
                "min": jnp.full((), jnp.inf, dtype),
            }
            if self.budgets:
                st["at_budget"] = jnp.zeros((len(self.budgets),), dtype)
            if self.targets:
                st["time_to"] = jnp.full((len(self.targets),), jnp.inf, dtype)
            if self.quantiles:
                st["hist"] = jnp.zeros((self.bins,), dtype)
            carry[f] = st
        return carry

    def update_carry(self, carry: dict, metrics, dclock) -> dict:
        """Fold one iteration's (acc, test_err, z_err) + clock increments."""
        vals = dict(zip(METRIC_FIELDS, metrics))
        k = carry["k"]
        dtype = carry["clock"].dtype
        clock = carry["clock"] + jnp.asarray(dclock, dtype)
        x = clock[self.axis_index]
        first = k == 0
        new = {"k": k + 1, "clock": clock}
        for f in self.fields:
            # Cast into the carry dtype: the scan carry must keep a stable
            # dtype even when a kernel emits narrower metrics.
            st, m = carry[f], jnp.asarray(vals[f], dtype)
            # Welford over iterations: mean + M2 in one pass.
            kf = (k + 1).astype(m.dtype)
            delta = m - st["mean"]
            mean = st["mean"] + delta / kf
            out = {
                "last": m,
                "mean": mean,
                "m2": st["m2"] + delta * (m - mean),
                "min": jnp.minimum(st["min"], m),
            }
            if self.budgets:
                B = jnp.asarray(self.budgets, m.dtype)
                # value at the LAST iteration completed within each budget;
                # the first iteration seeds every budget (hold-first, the
                # resample_runs convention for runs that start past B).
                out["at_budget"] = jnp.where(
                    (x <= B) | first, m, st["at_budget"]
                )
            if self.targets:
                tg = jnp.asarray(self.targets, m.dtype)
                out["time_to"] = jnp.where(
                    (m <= tg) & jnp.isinf(st["time_to"]), x, st["time_to"]
                )
            if self.quantiles:
                idx = _bin_index(self, m)
                out["hist"] = st["hist"].at[idx].add(1)
            new[f] = out
        return new

    def finalize_carry(self, carry: dict) -> Dict[str, jnp.ndarray]:
        """Collapse the carry to the flat output dict (still in-jit)."""
        out = {}
        for i, ax in enumerate(CLOCK_AXES):
            out[f"{ax}/final"] = carry["clock"][i]
        k = carry["k"]
        for f in self.fields:
            st = carry[f]
            out[f"{f}/final"] = st["last"]
            out[f"{f}/mean"] = st["mean"]
            out[f"{f}/var"] = st["m2"] / jnp.maximum(k - 1, 1).astype(
                st["m2"].dtype
            )
            out[f"{f}/min"] = st["min"]
            if self.budgets:
                out[f"{f}/at_budget"] = st["at_budget"]
            if self.targets:
                out[f"{f}/time_to"] = st["time_to"]
            if self.quantiles:
                cdf = jnp.cumsum(st["hist"])
                q = jnp.asarray(self.quantiles, cdf.dtype)
                idx = jnp.clip(
                    jnp.searchsorted(cdf, q * k.astype(cdf.dtype)),
                    0, self.bins - 1,
                )
                out[f"{f}/quantiles"] = self.lo + (
                    idx.astype(cdf.dtype) + 0.5
                ) * (self.hi - self.lo) / self.bins
        return out


def _bin_index(spec: Reduction, m):
    """Histogram bin of a metric value, edge-clipped (jnp and numpy agree)."""
    scaled = jnp.floor(
        (m - spec.lo) / (spec.hi - spec.lo) * spec.bins
    )
    return jnp.clip(scaled, 0, spec.bins - 1).astype(jnp.int32)


def reduce_trace(spec: Reduction, trace) -> Dict[str, np.ndarray]:
    """Post-hoc reference: apply ``spec`` to a materialized `Trace`.

    The correctness contract of the streaming layer: for every kernel and
    execution tier, the in-scan fold equals this numpy reduction of the
    full per-iteration record to <= 1e-5. Also the upgrade path for old
    materialized sweeps — reduce once, then compare against streaming
    runs at fleet scale.
    """
    clocks = {
        "sim_time": np.asarray(trace.sim_time, dtype=np.float64),
        "comm_cost": np.asarray(trace.comm_cost, dtype=np.float64),
    }
    x = clocks[spec.x]
    out: Dict[str, np.ndarray] = {
        f"{ax}/final": clocks[ax][-1] for ax in CLOCK_AXES
    }
    for f in spec.fields:
        ys = np.asarray(getattr(trace, f), dtype=np.float64)
        n = len(ys)
        out[f"{f}/final"] = ys[-1]
        out[f"{f}/mean"] = ys.mean()
        out[f"{f}/var"] = ys.var(ddof=1) if n > 1 else np.float64(0.0)
        out[f"{f}/min"] = ys.min()
        if spec.budgets:
            idx = np.searchsorted(x, np.asarray(spec.budgets), "right") - 1
            out[f"{f}/at_budget"] = ys[np.clip(idx, 0, n - 1)]
        if spec.targets:
            t2t = np.full(len(spec.targets), np.inf)
            for j, tg in enumerate(spec.targets):
                hit = np.nonzero(ys <= tg)[0]
                if len(hit):
                    t2t[j] = x[hit[0]]
            out[f"{f}/time_to"] = t2t
        if spec.quantiles:
            bins = np.clip(
                np.floor((ys - spec.lo) / (spec.hi - spec.lo) * spec.bins),
                0, spec.bins - 1,
            ).astype(int)
            hist = np.bincount(bins, minlength=spec.bins).astype(np.float64)
            cdf = np.cumsum(hist)
            q = np.asarray(spec.quantiles, dtype=np.float64)
            idx = np.clip(np.searchsorted(cdf, q * n), 0, spec.bins - 1)
            out[f"{f}/quantiles"] = spec.lo + (idx + 0.5) * (
                spec.hi - spec.lo
            ) / spec.bins
    if spec.final_x:
        out["final_x"] = np.asarray(trace.final_x)
        out["final_z"] = np.asarray(trace.final_z)
    return {k: np.asarray(v) for k, v in out.items()}
