"""MethodKernel protocol: one pure step function per algorithm (DESIGN.md §8).

Every consensus method in the repo — the paper's (c)sI-/I-ADMM, the §V-A
baselines (W-ADMM, D-ADMM, DGD, EXTRA), and the beyond-paper variants
(pI-ADMM, cq-sI-ADMM) — is expressed once, as a kernel with a single
``step`` function. Execution backends are *derived* from the kernel by
`repro.methods.driver`:

- serial:  ``lax.scan(step)`` over iterations, one run per dispatch;
- batched: ``vmap`` of the *same* scan over a leading runs axis, one jit
  trace and one device dispatch per static-signature group;
- sharded: ``shard_map`` of the batched scan over a 1-D device mesh on
  the runs axis — each device executes its local runs, bitwise equal to
  batched (DESIGN.md §9).

The contract that makes this work is the host/device split of DESIGN.md
§2: ``prepare`` samples everything random host-side (numpy) and returns
plain arrays; ``setup``/``init``/``step``/``final`` are pure jax functions
of those arrays, so stacking runs on a leading axis and vmapping is a
semantics-preserving transform (asserted elementwise in
``tests/test_methods.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Network
from repro.core.problems import LeastSquaresProblem

__all__ = [
    "Prepared",
    "MethodKernel",
    "KERNELS",
    "register",
    "get_kernel",
]


@dataclasses.dataclass
class Prepared:
    """Host-side output of :meth:`MethodKernel.prepare` for ONE run.

    Attributes:
      consts: per-run constant arrays (data, targets, schedules' scalars).
        Stackable on a leading runs axis across a batch.
      steps: per-step input arrays, leading axis = iters (agent schedule,
        decode weights, step sizes, host-sampled noise). May be empty for
        methods whose iterations consume no per-step data (gossip).
      statics: hashable jit statics; must be identical across a batch
        (shapes, K, exact_x, iters, ...).
      max_statics: statics the batched driver reconciles with ``max()``
        across runs (e.g. the masked gather bound MU) — the corresponding
        runtime value lives in ``consts`` so runs with different values
        still share one trace (DESIGN.md §7).
      comm: cumulative communication units per iteration, host accounting.
      sim_time: cumulative simulated seconds per iteration.
    """

    consts: Tuple[np.ndarray, ...]
    steps: Tuple[np.ndarray, ...]
    statics: Dict[str, object]
    max_statics: Dict[str, int]
    comm: np.ndarray
    sim_time: np.ndarray


class MethodKernel:
    """One algorithm = one ``step`` function plus host-side preparation.

    Subclasses implement:

    - ``config(case)``: build the method-specific config from a duck-typed
      `repro.experiments.sweep.Case` (any object with the right fields).
    - ``static_signature(problem, cfg, iters)``: hashable key of everything
      forcing a fresh jit trace; equal keys batch into one dispatch.
    - ``prepare(problem, net, cfg, iters) -> Prepared``: host-side numpy.
    - ``setup(consts, statics) -> aux``: in-jit, once per run — derived
      constants (Gram matrices, flat views, solve operators).
    - ``init(aux, statics) -> state``: initial scan carry (a dict pytree).
    - ``step(state, inp, aux, statics) -> (state, (acc, test_err, z_err))``:
      ONE iteration; ``inp`` is the per-step slice of ``Prepared.steps``.
    - ``final(state, aux, statics) -> (x, z)``: per-agent iterates (N, p, d)
      and the consensus model (p, d).
    """

    name: str = "?"

    def config(self, case):
        raise NotImplementedError

    def static_signature(
        self, problem: LeastSquaresProblem, cfg, iters: int
    ) -> tuple:
        """Hashable key of everything forcing a fresh jit trace.

        Convention: variant execution modes extend the family's base
        tuple with a tagged suffix rather than replacing it — async runs
        append ``("async", staleness_cap)`` (DESIGN.md §13), adaptive
        controller runs append ``("adaptive", n_arms, algo)``
        (DESIGN.md §15). Suffixes keep base grids batching exactly as
        before while guaranteeing a variant run never merges into a
        group whose kernel would mis-build its config.
        """
        raise NotImplementedError

    def prepare(
        self,
        problem: LeastSquaresProblem,
        net: Network,
        cfg,
        iters: int,
    ) -> Prepared:
        raise NotImplementedError

    def max_statics_bound(
        self, problem: LeastSquaresProblem, cfg, iters: int
    ) -> Dict[str, int]:
        """Exact bound on :attr:`Prepared.max_statics` WITHOUT preparing.

        The streaming-reduction sharded path (DESIGN.md §12) prepares runs
        lazily per memory chunk, so the global jit statics must be known
        up front from (problem, cfg) alone — ``prepare()`` would cost the
        very O(R x iters) host memory the path exists to avoid. Kernels
        whose ``prepare`` emits ``max_statics`` must override this with a
        value >= every run's prepared value (equal keys); the driver
        verifies each chunk against it. Kernels with empty ``max_statics``
        inherit this default.
        """
        return {}

    def setup(self, consts, statics):
        return consts

    def init(self, aux, statics):
        raise NotImplementedError

    def step(self, state, inp, aux, statics):
        raise NotImplementedError

    def final(self, state, aux, statics):
        raise NotImplementedError

    # -- shared aux/state/metric plumbing ----------------------------------

    @staticmethod
    def lsq_aux(O, T, x_star, O_test, T_test):
        """Aux base for kernels that keep the raw (N, b, ...) data views:
        everything :meth:`metrics` consumes plus shape/dtype bookkeeping."""
        N, b, p = O.shape
        return dict(
            O=O, T=T, b=b,
            x_star=x_star,
            xs_norm=jnp.linalg.norm(x_star),
            O_test=O_test, T_test=T_test,
            shape=(N, p, T.shape[2]), dtype=O.dtype,
        )

    @staticmethod
    def xyz_state(aux):
        """Zero-initialized (x, y, z) carry of the incremental-ADMM family."""
        N, p, d = aux["shape"]
        zeros = jnp.zeros((N, p, d), aux["dtype"])
        return dict(x=zeros, y=zeros, z=jnp.zeros((p, d), aux["dtype"]))

    # -- shared metric algebra (eq. 23 accuracy, test MSE, z error) --------

    @staticmethod
    def metrics(x, z, aux):
        """Standard per-step metrics from aux['x_star']/test operands."""
        x_star, xs_norm = aux["x_star"], aux["xs_norm"]
        N = x.shape[0]
        acc = jnp.mean(
            jnp.linalg.norm((x - x_star[None]).reshape(N, -1), axis=1)
            / jnp.maximum(xs_norm, 1e-12)
        )
        if "Gt" in aux:
            # ||O z - T||^2 / n = (z'Gz - 2<z,C> + ||T||^2) / n via the test
            # set's precomputed Gram/cross matrices (p x p per step).
            test_err = (
                jnp.einsum("pd,pq,qd->", z, aux["Gt"], z)
                - 2.0 * jnp.vdot(z, aux["Ct"])
                + aux["TTt"]
            ) / aux["n_test"]
        else:
            r = aux["O_test"] @ z - aux["T_test"]
            test_err = jnp.mean(jnp.sum(r * r, axis=-1))
        z_err = jnp.linalg.norm(z - x_star) / jnp.maximum(xs_norm, 1e-12)
        return acc, test_err, z_err


KERNELS: Dict[str, MethodKernel] = {}


def register(kernel: MethodKernel, *names: str) -> MethodKernel:
    """Add a kernel to the method registry (name -> singleton instance).

    Extra ``names`` register the SAME instance under several method
    names (sI-/csI-/I-ADMM are one kernel whose behavior is fully
    determined by the run config), so they share jit caches and batch
    into one dispatch when shapes allow.
    """
    for name in names or (kernel.name,):
        if name in KERNELS:
            raise ValueError(f"duplicate method kernel {name!r}")
        KERNELS[name] = kernel
    return kernel


def get_kernel(name: str) -> MethodKernel:
    if name not in KERNELS:
        raise KeyError(
            f"unknown method {name!r}; known: {sorted(KERNELS)}"
        )
    return KERNELS[name]
