"""Incremental (c)sI-/I-ADMM as a MethodKernel (paper Algorithms 1 & 2).

The ONE step implementation for the whole ADMM family (DESIGN.md §8): the
zero-weight-masked, flat-gather scan body that previously existed twice
(a serial `dynamic_slice` variant and a masked batched clone) is now the
canonical kernel, executed serially or vmapped by `repro.methods.driver`.

Per step (active agent i = i_k, eqs. 5a/5b/4c):

  x_i^{k+1} = (tau^k x_i^k + rho z^k + y_i^k - G_i) / (rho + tau^k)
  y_i^{k+1} = y_i^k + rho gamma^k (z^k - x_i^{k+1})
  z^{k+1}   = z^k + [ (x_i^{k+1}-x_i^k) - (y_i^{k+1}-y_i^k)/rho ] / N

with G_i the decoded mini-batch gradient (eq. 6). The coded
encode->decode path collapses host-side to per-partition weights
w = (a^T B)/K; the device step computes one masked sub-batch gradient
message per ECN partition and hands decode-combine + eq. (5a) to the
fused Pallas kernel `repro.kernels.ops.coded_admm_update` (interpret
mode off-TPU), so serial, batched, and mesh-sharded execution all
exercise the same fused hot path (DESIGN.md §5, §9). The sub-batch
size mu = M/((S+1)K) is a *runtime* input masked against the static
bound MU, which is what lets a whole straggler-tolerance sweep share
one jit trace (DESIGN.md §7). I-ADMM (exact_x) replaces the stochastic
x-update with the closed-form full-batch solve (eq. 4a).

Subclass hooks ``_perturb_x`` (pI-ADMM, `repro.methods.privacy`),
``_token_increment`` (cq-sI-ADMM, `repro.methods.compression`) and
``_select_arm`` (a-csI-ADMM, `repro.control.kernel`) extend the family
without touching the drivers. ``_select_arm`` runs FIRST: an adaptive
subclass stacks every arm's per-step schedule on an extra axis and the
hook resolves the carry-resident controller state into this iteration's
live row, handing the base step a pseudo-``inp`` with the standard
layout — the base algebra never learns arms exist (DESIGN.md §15).

Event-driven mode (DESIGN.md §13): when the run's `TimingModel` is
async (``tau_max > 0`` or ``churn_rate > 0``) the token increment dz of
iteration k lands with a bounded simulated delay instead of
immediately. The kernel carries a ``pend`` ring buffer of
``staleness_cap`` in-flight increments; host-precomputed write/read
slots and the activity gate ride as THREE per-step arrays appended
AFTER every subclass extra (read via negative indices, so the
privacy/compression hooks' positional inputs are untouched). Skipped
activations (crashed agent, undecodable churned pattern —
`repro.core.admm.make_schedule`) gate x/y/dz to exact zeros. The sync
path (``tau_max = 0``, ``churn_rate = 0``) takes the EXACT pre-async
code — same statics, same steps, same jit trace — so synchronous runs
stay bit-identical.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.admm import ADMMConfig, make_schedule
from repro.core.coding import GradientCode, make_code
from repro.core.graph import Network
from repro.core.problems import LeastSquaresProblem
from repro.core.timing import TimingModel
from repro.kernels.ops import coded_admm_update, fit_block_n

from .base import MethodKernel, Prepared, register

__all__ = ["ADMMRun", "IncrementalADMM", "ADMM_KERNEL"]


@dataclasses.dataclass(frozen=True)
class ADMMRun:
    """Per-run config of the ADMM family: hyper-params + timing model."""

    cfg: ADMMConfig
    timing: Optional[TimingModel] = None
    code: Optional[GradientCode] = None


class IncrementalADMM(MethodKernel):
    """sI-ADMM / csI-ADMM / I-ADMM (ONE kernel, three registry names).

    The behavioral switches (exact_x, scheme, S) all live in the
    `ADMMConfig`, so a single instance serves all three paper names and
    ``name`` is the family tag — mixed sI/csI grids with equal shapes
    share a static signature and batch into one dispatch, exactly like
    the pre-refactor family key."""

    name = "admm"

    # -- host side ---------------------------------------------------------

    def config(self, case) -> ADMMRun:
        return ADMMRun(case.admm_config(), case.timing_model())

    def static_signature(
        self, problem: LeastSquaresProblem, run: ADMMRun, iters: int
    ) -> tuple:
        cfg = run.cfg
        sig = (
            self.name,
            problem.N, problem.b, problem.p, problem.d,
            problem.O_test.shape[0],
            cfg.K, problem.b // cfg.K, cfg.exact_x, iters,
        )
        if run.timing is not None and run.timing.is_async:
            # Async runs carry the pend ring + extra step inputs: their
            # own trace, one dispatch group per ring depth (DESIGN.md
            # §13). Sync runs keep the exact pre-async signature.
            sig += ("async", run.timing.staleness_cap)
        return sig

    def prepare(
        self,
        problem: LeastSquaresProblem,
        net: Network,
        run: ADMMRun,
        iters: int,
    ) -> Prepared:
        cfg = run.cfg
        cfg.validate()
        timing = run.timing or TimingModel()
        code = run.code or make_code(cfg.scheme, cfg.K, cfg.S, seed=cfg.seed)
        if code.K != cfg.K or code.S != cfg.S:
            raise ValueError("code does not match config (K, S)")

        sched = make_schedule(cfg, net, code, timing, iters, problem.b)
        dt = problem.O.dtype
        # Encode->decode folds to per-partition weights host-side: the
        # decoded mini-batch gradient (eq. 6) is
        #   G = (1/K) sum_j a_j sum_t B[j,t] g~_t = sum_t w_t g~_t.
        W_steps = (sched["decode"].astype(dt) @ code.B.astype(dt)) / cfg.K
        # Runtime live-partition mask for the fused kernel (DESIGN.md
        # §11): partition t is live iff some alive ECN covers it, so the
        # kernel hard-zeroes the rest independently of the folded
        # weights. Exact-decode-at-R vs approximate-decode-at-deadline
        # is already selected per iteration by `make_schedule` — the
        # mask and coefficients are per-step DATA, so every deadline
        # pattern shares one jit trace.
        cover = np.abs(code.B) > 1e-12  # (K ecn, K partition)
        wmask = (sched["alive"].astype(dt) @ cover.astype(dt)) > 0
        # One token hop per activation; response + link time per iter.
        # Compressed tokens (cq-sI-ADMM) ship fewer bits, so their
        # hop's link time scales by the same true bit cost the
        # communication accounting charges (DESIGN.md §10).
        sim_time = np.cumsum(
            sched["resp_time"]
            + sched["link_time"] * self._comm_per_iter(run, problem)
        )
        steps = self._extra_steps(
            run, problem, iters,
            (
                sched["agents"],
                sched["offsets"],
                W_steps,
                sched["tau"].astype(dt),
                sched["gamma"].astype(dt),
                wmask.astype(dt),
            ),
        )
        statics = self._statics(run, problem, iters, sched)
        if timing.is_async:
            # Event-driven mode (DESIGN.md §13): write/read ring slots +
            # activity gate append AFTER subclass extras — the step reads
            # them via negative indices, so hook inputs keep their
            # positions. Staleness is sampled on the run's own clock
            # (stream [7, seed]); delay d in [0, D-1] steps lands the
            # increment written at iteration k at the end of iteration
            # k + d (d = 0 is the synchronous landing).
            D = timing.staleness_cap
            delta = timing.staleness_steps(
                sim_time, np.random.default_rng([7, cfg.seed])
            )
            k = np.arange(iters)
            steps = steps + (
                ((k + delta) % D).astype(np.int32),
                (k % D).astype(np.int32),
                sched["act"].astype(dt),
            )
            statics = dict(statics, ASYNC=True, D=D)
        return Prepared(
            consts=(
                problem.O,
                problem.T,
                problem.x_star().astype(dt),
                problem.O_test,
                problem.T_test,
                np.asarray(cfg.rho, dtype=dt),
                np.asarray(sched["mu"], dtype=np.int32),
            ),
            steps=steps,
            statics=statics,
            max_statics=dict(MU=int(sched["mu"])),
            comm=np.cumsum(np.full(iters, self._comm_per_iter(run, problem))),
            sim_time=sim_time,
        )

    def max_statics_bound(
        self, problem: LeastSquaresProblem, run: ADMMRun, iters: int
    ) -> dict:
        # Exact: make_schedule's mu IS M_bar // K (no sampling involved),
        # so chunked streaming execution shares one jit trace with the
        # eager batched path.
        return dict(MU=run.cfg.M_bar // run.cfg.K)

    def _statics(self, run: ADMMRun, problem, iters, sched) -> dict:
        return dict(
            name=self.name, iters=iters, P=sched["P"], K=run.cfg.K,
            N=problem.N, exact_x=run.cfg.exact_x,
        )

    def _extra_steps(self, run: ADMMRun, problem, iters, steps: tuple) -> tuple:
        """Hook: subclasses append host-sampled per-step arrays (noise)."""
        return steps

    def _comm_per_iter(self, run: ADMMRun, problem) -> float:
        return 1.0

    # -- device side -------------------------------------------------------

    def setup(self, consts, statics):
        O, T, x_star, O_test, T_test, rho, mu = consts
        N, b, p = O.shape
        d = T.shape[2]
        MU = statics["MU"]
        rows = jnp.arange(MU)
        aux = dict(
            x_star=x_star,
            xs_norm=jnp.linalg.norm(x_star),
            # test error via the test set's Gram/cross matrices: p x p per
            # step instead of n_test x p (EXPERIMENTS.md §Perf).
            Gt=O_test.T @ O_test,
            Ct=O_test.T @ T_test,
            TTt=jnp.sum(T_test * T_test),
            n_test=O_test.shape[0],
            # Flat views: per-step mini-batches gather the K*MU needed rows
            # straight out of the (N*b, p) pool instead of copying the
            # active agent's whole (b, p) block.
            O_flat=O.reshape(N * b, p),
            T_flat=T.reshape(N * b, d),
            rows=rows,
            valid=(rows < mu).astype(O.dtype),
            inv_mu=1.0 / mu.astype(O.dtype),
            part=jnp.arange(statics["K"]),
            rho=rho,
            b=b,
            shape=(N, p, d),
            dtype=O.dtype,
            # Static tile for the fused Pallas x-update (lane-legal, no
            # gross padding of the flat (p*d,) parameter vector).
            block_n=fit_block_n(p * d),
        )
        if statics["exact_x"]:
            # I-ADMM exact solve operands: (O^T O / b + rho I), O^T T / b.
            aux["H"] = jnp.einsum("nbp,nbq->npq", O, O) / b
            aux["rhs0"] = jnp.einsum("nbp,nbd->npd", O, T) / b
            aux["eye"] = jnp.eye(p, dtype=O.dtype)
        return aux

    def init(self, aux, statics):
        state = self.xyz_state(aux)
        if statics.get("ASYNC"):
            # Ring buffer of in-flight token increments (DESIGN.md §13):
            # slot s holds the sum of increments landing at the end of
            # the next iteration k with k % D == s.
            N, p, d = aux["shape"]
            state["pend"] = jnp.zeros((statics["D"], p, d), aux["dtype"])
        return state

    def step(self, state, inp, aux, statics):
        state, inp, aux = self._select_arm(state, inp, aux, statics)
        i, off, w, tk, gk = inp[0], inp[1], inp[2], inp[3], inp[4]
        x, y, z = state["x"], state["y"], state["z"]
        xi, yi = x[i], y[i]
        rho = aux["rho"]
        N = statics["N"]

        if statics["exact_x"]:
            x_new = jnp.linalg.solve(
                aux["H"][i] + rho * aux["eye"], aux["rhs0"][i] + rho * z + yi
            )
        else:
            # One gather of all K partitions' sub-batches; rows >= mu carry
            # weight exactly 0 (their clamped OOB gathers contribute exact
            # zeros to the gradient sums — batched == serial elementwise).
            idx = (
                i * aux["b"]
                + aux["part"][:, None] * statics["P"]
                + off
                + aux["rows"][None, :]
            )
            Ob = aux["O_flat"][idx]  # (K, MU, p)
            Tb = aux["T_flat"][idx]  # (K, MU, d)
            # Per-ECN coded message: the masked sub-batch gradient g~_j
            # (eq. 6 before decode), one row of the fused kernel's msgs.
            r = (aux["valid"] * aux["inv_mu"])[None, :, None] * (Ob @ xi - Tb)
            msgs = jnp.einsum("kmp,kmd->kpd", Ob, r).reshape(
                statics["K"], -1
            )
            # Fused decode-combine + eq. (5a) through the Pallas hot path
            # (DESIGN.md §5); w already folds a^T B / K, so coeffs = w,
            # and inp[5] is the live-partition mask of this iteration's
            # alive set (exact-at-R or deadline-truncated, DESIGN.md §11).
            x_new = coded_admm_update(
                msgs, w, xi.ravel(), yi.ravel(), z.ravel(), tk, rho,
                inp[5], block_n=aux["block_n"],
            ).reshape(xi.shape)

        x_new = self._perturb_x(x_new, inp, aux, statics)
        if statics.get("ASYNC"):
            # Skipped activation (crashed agent / undecodable pattern):
            # act = 0 freezes x and y, making dz an exact zero below.
            # where-gating (not act-scaling) keeps the act = 1 path
            # bitwise identical to the ungated computation.
            act = inp[-1]
            x_new = jnp.where(act > 0, x_new, xi)
        y_new = yi + rho * gk * (z - x_new)  # eq. (5b)
        if statics.get("ASYNC"):
            y_new = jnp.where(act > 0, y_new, yi)
        dz = ((x_new - xi) - (y_new - yi) / rho) / N  # eq. (4c) increment
        state = dict(state, x=x.at[i].set(x_new), y=y.at[i].set(y_new))
        state = self._token_update(state, dz, inp, aux, statics)
        return state, self.metrics(state["x"], state["z"], aux)

    def _select_arm(self, state, inp, aux, statics):
        """Hook: the online controller resolves arm-stacked step inputs.

        Runs before anything else in :meth:`step`. The base family is
        non-adaptive — identity, so the synchronous/static paths keep
        their exact pre-controller trace. `repro.control.kernel`
        overrides this to pull a bandit arm from carry state, feed back
        the observed-response reward, and return a standard-layout
        pseudo-``inp`` selecting the live arm's schedule row
        (DESIGN.md §15).
        """
        return state, inp, aux

    def _perturb_x(self, x_new, inp, aux, statics):
        """Hook: pI-ADMM adds Gaussian noise to the shared primal."""
        return x_new

    def _token_increment(self, state, dz, inp, aux, statics):
        """Hook: compute the transmitted token increment.

        Returns ``(state_updates, c)`` where ``c`` is the increment the
        active agent actually ships (cq-sI-ADMM compresses dz here) and
        ``state_updates`` are carry entries the hook mutates (e.g. the
        error-feedback residual). Split from the z application so the
        async path can route ``c`` through the pend ring and gate the
        hook's state on the activity mask without knowing its keys.
        """
        return {}, dz

    def _token_update(self, state, dz, inp, aux, statics):
        """Apply the token increment: directly (sync) or via the pend
        ring with bounded staleness (async, DESIGN.md §13)."""
        upd, c = self._token_increment(state, dz, inp, aux, statics)
        if not statics.get("ASYNC"):
            return dict(state, **upd, z=state["z"] + c)
        wslot, rslot, act = inp[-3], inp[-2], inp[-1]
        # Dead activations transmit nothing and leave hook state alone.
        upd = {k: jnp.where(act > 0, v, state[k]) for k, v in upd.items()}
        pend = state["pend"].at[wslot].add(
            jnp.where(act > 0, c, jnp.zeros_like(c))
        )
        # Land every increment maturing at this iteration's boundary
        # (the read slot includes this step's own write when delta = 0 —
        # the synchronous landing).
        z = state["z"] + pend[rslot]
        pend = pend.at[rslot].set(jnp.zeros_like(state["z"]))
        return dict(state, **upd, z=z, pend=pend)

    def final(self, state, aux, statics):
        z = state["z"]
        if statics.get("ASYNC"):
            # Flush in-flight increments: the run ends, updates land.
            z = z + state["pend"].sum(axis=0)
        return state["x"], z


ADMM_KERNEL = register(IncrementalADMM(), "sI-ADMM", "csI-ADMM", "I-ADMM")
