"""pI-ADMM: privacy-perturbed incremental ADMM (arXiv 2003.10615).

The active agent perturbs the primal variable it shares with Gaussian
noise before the dual/token updates, the first-order perturbation
mechanism of "Privacy-Preserving Incremental ADMM for Decentralized
Consensus Optimization" (Ding et al.). The noise standard deviation
decays as sigma_k = sigma / sqrt(k) — the diminishing-noise schedule
that keeps the O(1/k) convergence of Theorem 2 up to a variance floor —
and is sampled HOST-side per iteration (`Prepared.steps`), so the device
step stays a pure function and the kernel batches like every other
method (DESIGN.md §8).

Everything else (mini-batch oracle, coding, straggler timing) is
inherited from `repro.methods.admm.IncrementalADMM`: the privacy variant
is literally the sI-ADMM step plus one hook.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .admm import ADMMRun, IncrementalADMM
from .base import register

__all__ = ["PrivacyRun", "PrivateADMM", "PI_ADMM"]


@dataclasses.dataclass(frozen=True)
class PrivacyRun(ADMMRun):
    """ADMM run config + primal perturbation scale (noise std at k=1)."""

    sigma: float = 0.01


class PrivateADMM(IncrementalADMM):
    name = "pI-ADMM"

    def config(self, case) -> PrivacyRun:
        return PrivacyRun(
            case.admm_config(), case.timing_model(), sigma=case.sigma
        )

    def _extra_steps(
        self, run: PrivacyRun, problem, iters, steps: tuple
    ) -> tuple:
        # Composite seed sequence: scalar-seeded streams (schedule uses
        # cfg.seed, stragglers cfg.seed + 1) never collide with [tag, seed]
        # sequences, so multi-seed grid arms stay independent.
        rng = np.random.default_rng([2, run.cfg.seed])
        dt = problem.O.dtype
        sigma_k = run.sigma / np.sqrt(np.arange(1, iters + 1))
        noise = sigma_k[:, None, None] * rng.standard_normal(
            (iters, problem.p, problem.d)
        )
        return steps + (noise.astype(dt),)

    def _perturb_x(self, x_new, inp, aux, statics):
        return x_new + inp[6]


PI_ADMM = register(PrivateADMM())
