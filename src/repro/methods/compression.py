"""cq-sI-ADMM: communication-compressed token updates (arXiv 2501.13516).

Compressed consensus in the style of "Communication-Efficient Stochastic
ADMM with Quantization": the token increment dz an agent would transmit
(eq. 4c) is compressed before it is applied, with an error-feedback
accumulator so the compression error is re-injected instead of lost —
the standard trick that preserves convergence under biased compressors.

Two compressors, both pure in-step functions:

- ``topk``: keep the ceil(frac * p*d) largest-|.| entries of the
  residual-corrected increment (k is a jit static; `jax.lax.top_k`).
- ``quant``: stochastic uniform quantization to 2^bits - 1 levels of
  |u|/max|u|, with the rounding randomness sampled HOST-side per step
  (`Prepared.steps`) so serial and batched execution see identical bits.

Communication accounting reflects the compression, including the side
information: a topk hop costs k*(32 + log2(p*d))/(32*p*d) units (values
plus indices), a quant hop ((bits+1)*p*d + 32)/(32*p*d) units (sign +
magnitude per entry plus the per-token scale) — versus 1 unit for a
dense fp32 token — so accuracy-vs-communication sweeps compare honestly
against sI-ADMM.

Inherits the full coded mini-batch machinery from
`repro.methods.admm.IncrementalADMM` — the variant is one ``_token_update``
hook plus one extra carried state entry.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from .admm import ADMMRun, IncrementalADMM
from .base import register

__all__ = ["CompressionRun", "CompressedADMM", "CQ_SI_ADMM"]


@dataclasses.dataclass(frozen=True)
class CompressionRun(ADMMRun):
    """ADMM run config + token compressor choice."""

    compressor: str = "topk"  # "topk" | "quant"
    frac: float = 0.25  # topk: fraction of token entries kept
    bits: int = 8  # quant: bits per transmitted entry


class CompressedADMM(IncrementalADMM):
    name = "cq-sI-ADMM"

    def config(self, case) -> CompressionRun:
        return CompressionRun(
            case.admm_config(),
            case.timing_model(),
            compressor=case.compressor,
            frac=case.frac,
            bits=case.bits,
        )

    def static_signature(self, problem, run: CompressionRun, iters) -> tuple:
        base = super().static_signature(problem, run, iters)
        if run.compressor == "topk":
            return base + ("topk", self._k_keep(run, problem))
        return base + ("quant", run.bits)

    @staticmethod
    def _k_keep(run: CompressionRun, problem) -> int:
        if not 0.0 < run.frac <= 1.0:
            raise ValueError(f"frac must be in (0, 1], got {run.frac}")
        return max(1, math.ceil(run.frac * problem.p * problem.d))

    def _statics(self, run: CompressionRun, problem, iters, sched) -> dict:
        statics = super()._statics(run, problem, iters, sched)
        statics["compressor"] = run.compressor
        if run.compressor == "topk":
            statics["k_keep"] = self._k_keep(run, problem)
        elif run.compressor == "quant":
            if run.bits < 1:
                raise ValueError(f"bits must be >= 1, got {run.bits}")
            statics["levels"] = 2 ** run.bits - 1
        else:
            raise ValueError(f"unknown compressor {run.compressor!r}")
        return statics

    def _extra_steps(self, run: CompressionRun, problem, iters, steps):
        if run.compressor != "quant":
            return steps
        # [tag, seed] sequence: disjoint from every scalar-seeded stream
        # (schedule, stragglers) and from privacy's [2, seed].
        rng = np.random.default_rng([3, run.cfg.seed])
        unif = rng.random((iters, problem.p, problem.d))
        return steps + (unif.astype(problem.O.dtype),)

    def _comm_per_iter(self, run: CompressionRun, problem) -> float:
        pd = problem.p * problem.d
        if run.compressor == "topk":
            # Each kept entry ships its 32-bit value plus a log2(p*d)-bit
            # index, relative to the 32*p*d-bit dense token.
            idx_bits = max(1, math.ceil(math.log2(pd)))
            return self._k_keep(run, problem) * (32 + idx_bits) / (32 * pd)
        # Sign + magnitude per entry, plus one fp32 scale per token.
        return ((run.bits + 1) * pd + 32) / (32 * pd)

    def init(self, aux, statics):
        state = super().init(aux, statics)
        p, d = aux["shape"][1], aux["shape"][2]
        state["e"] = jnp.zeros((p, d), aux["dtype"])  # compression residual
        return state

    def _token_increment(self, state, dz, inp, aux, statics):
        u = dz + state["e"]  # error feedback: re-inject past residual
        if statics["compressor"] == "topk":
            flat = u.reshape(-1)
            _, idx = jax.lax.top_k(jnp.abs(flat), statics["k_keep"])
            mask = jnp.zeros_like(flat).at[idx].set(1.0)
            c = (flat * mask).reshape(u.shape)
        else:
            L = statics["levels"]
            scale = jnp.max(jnp.abs(u))
            y = jnp.abs(u) / jnp.maximum(scale, 1e-30) * L
            q = jnp.floor(y + inp[6])  # stochastic rounding
            c = jnp.where(
                scale > 0.0, jnp.sign(u) * q * scale / L, jnp.zeros_like(u)
            )
        return {"e": u - c}, c


CQ_SI_ADMM = register(CompressedADMM())
