"""Gossip baselines (D-ADMM, DGD, EXTRA) as MethodKernels (paper §V-A).

Every agent updates every iteration using all its neighbors — 2|E|
directed messages per iteration versus the incremental methods' single
token hop. All three consume full local gradients, as in the original
methods; the consensus model reported in metrics is the agent mean.

Simulated wall-clock: a round costs the slowest agent's compute plus its
serialized per-neighbor link transfers (`TimingModel.gossip_round_times`,
DESIGN.md §10), the synchronous-decentralized accounting in the style of
EXTRA-era analyses (arXiv 1503.08855) — so gossip traces live on the same
accuracy-vs-running-time axis as the paper's incremental methods.
Timing draws use the composite seed stream [4, seed] (disjoint from the
scalar-seeded ADMM schedule streams and privacy/quantization [2|3, seed]).

Event-driven mode (DESIGN.md §13): when the run's `TimingModel.is_async`,
each kernel switches to a delayed-broadcast model. Agents publish their
iterates into a depth-D history ring (carried scan state); each round,
agent j's *published* value is read at a per-agent staleness
``delta[k, j]`` drawn host-side against the run's cumulative clock
(``staleness_steps``), while gradients are always evaluated at the
agent's own fresh iterate. Crashed agents (``sample_churn``, seed stream
[6, seed]; staleness uses [7, seed]) freeze — their last published value
persists in neighbors' mixing without reweighting, the
frozen-neighbor model of dynamic-network gossip (arXiv 1503.08855).
``delta = 0`` reads the previous round's publication — exactly the
current iterate — so all three methods degenerate to the synchronous
iterates (to within compiler reassociation of the distinct async
program; the hard bit-identity guarantee is ``tau_max = 0``, which
keeps the synchronous trace). D-ADMM achieves this with a dual-first
update from the pre-update iterate — the stale age-1 publication at
``delta = 0`` is x_k itself, so the dual accumulates exactly the
synchronous residuals (DESIGN.md §13).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Network, metropolis_weights
from repro.core.problems import LeastSquaresProblem
from repro.core.timing import TimingModel

from .base import MethodKernel, Prepared, register

__all__ = [
    "GossipRun",
    "DADMM",
    "DGD",
    "EXTRA",
    "D_ADMM_K",
    "DGD_K",
    "EXTRA_K",
]


@dataclasses.dataclass(frozen=True)
class GossipRun:
    """Per-run config of a gossip baseline: step parameter + clock.

    ``param`` is rho for D-ADMM and alpha for DGD/EXTRA; ``seed`` drives
    the host-side timing draws (topology/data sampling stays with the
    problem, as everywhere else).
    """

    param: float
    diminishing: bool = False  # DGD: alpha_k = param / sqrt(k)
    timing: Optional[TimingModel] = None
    seed: int = 0


def _lsq_consts(problem: LeastSquaresProblem, mix: np.ndarray, *scalars):
    dt = problem.O.dtype
    return (
        problem.O,
        problem.T,
        mix.astype(dt),
        problem.x_star().astype(dt),
        problem.O_test,
        problem.T_test,
        *(np.asarray(s, dtype=dt) for s in scalars),
    )


class _GossipKernel(MethodKernel):
    """Shared shape/metric/timing plumbing for all-agents-per-step methods."""

    # How many past publications a step reads per agent: 1 for the
    # one-round-back mixing of DGD/D-ADMM, 2 for EXTRA's two-term
    # recursion. Staleness is clipped to D - _ages so the oldest read
    # is still live in the depth-D ring (DESIGN.md §13).
    _ages = 1

    def static_signature(
        self, problem: LeastSquaresProblem, run, iters: int
    ) -> tuple:
        sig = (
            self.name,
            problem.N, problem.b, problem.p, problem.d,
            problem.O_test.shape[0], iters,
        )
        timing = run.timing or TimingModel()
        if timing.is_async:
            sig = sig + ("async", timing.staleness_cap)
        return sig

    def _event_schedules(self, run: GossipRun, net: Network, iters: int, dt):
        """Host-side clock + async scan inputs (DESIGN.md §13).

        Returns ``(sim_time, extra_steps, extra_statics)``. Synchronous
        runs take the exact pre-async draw path (same rng stream [4,
        seed], same call sequence) so their clock — and their dispatch
        signature — is bit-identical to before the event-driven mode
        existed.
        """
        timing = run.timing or TimingModel()
        rng = np.random.default_rng([4, run.seed])
        if not timing.is_async:
            sim = np.cumsum(timing.gossip_round_times(net, iters, rng))
            return sim, (), {}
        comp, per_agent = timing.gossip_components(net, iters, rng)
        nominal = timing.gossip_round_from(comp, per_agent)
        up = np.ones((iters, net.N), dtype=bool)
        if timing.churn_rate > 0:
            # Churn is evaluated at iteration start times on the
            # churn-free provisional clock (one-way coupling, §13).
            starts = np.concatenate([[0.0], np.cumsum(nominal)[:-1]])
            up = timing.sample_churn(
                starts, net.N, np.random.default_rng([6, run.seed])
            )
        sim_time = np.cumsum(
            timing.gossip_round_from(comp, per_agent, alive=up)
        )
        D = timing.staleness_cap
        delta = timing.staleness_steps(
            sim_time, np.random.default_rng([7, run.seed]), n=net.N
        )
        delta = np.minimum(delta, D - self._ages)
        k = np.arange(iters)
        # Read slots oldest-first (EXTRA reads age 2 then age 1); the
        # publication of round k lands in slot k % D after all reads.
        rslots = tuple(
            ((k[:, None] - a - delta) % D).astype(np.int32)
            for a in range(self._ages, 0, -1)
        )
        steps = (
            ((k % D).astype(np.int32),)
            + rslots
            + (up.astype(dt),)
        )
        return sim_time, steps, dict(ASYNC=True, D=D)

    @staticmethod
    def _published(hist, rslot):
        """Per-agent stale reads: hist (D, N, p, d), rslot (N,) -> (N, p, d)."""
        return hist[rslot, jnp.arange(rslot.shape[0])]

    def _grad(self, aux, x):
        """Stacked full local gradients (N, p, d)."""
        O, T = aux["O"], aux["T"]
        return (
            jnp.einsum(
                "nbp,nbd->npd", O, jnp.einsum("nbp,npd->nbd", O, x) - T
            )
            / aux["b"]
        )

    def final(self, state, aux, statics):
        x = state["x"]
        return x, x.mean(0)


class DADMM(_GossipKernel):
    """Gossip decentralized consensus ADMM [14]/[9] (exact local solves)."""

    name = "D-ADMM"

    def config(self, case) -> GossipRun:
        return GossipRun(
            case.rho, timing=case.timing_model(), seed=case.seed
        )

    def prepare(self, problem, net: Network, run: GossipRun, iters: int):
        dt = problem.O.dtype
        consts = (
            problem.O,
            problem.T,
            net.adjacency.astype(dt),
            net.degree().astype(dt),
            problem.x_star().astype(dt),
            problem.O_test,
            problem.T_test,
            np.asarray(run.param, dtype=dt),
        )
        sim_time, extra, extra_statics = self._event_schedules(
            run, net, iters, dt
        )
        return Prepared(
            consts=consts,
            steps=extra,
            statics=dict(name=self.name, iters=iters, **extra_statics),
            max_statics={},
            comm=np.cumsum(np.full(iters, 2.0 * net.E)),
            sim_time=sim_time,
        )

    def setup(self, consts, statics):
        O, T, A, deg, x_star, O_test, T_test, rho = consts
        aux = self.lsq_aux(O, T, x_star, O_test, T_test)
        N, b, p = O.shape
        H = jnp.einsum("nbp,nbq->npq", O, O) / b
        eye = jnp.eye(p, dtype=O.dtype)
        aux.update(
            A=A, deg=deg, rho=rho,
            rhs0=jnp.einsum("nbp,nbd->npd", O, T) / b,
            # Per-agent solve operator: (H_i + 2 rho d_i I)
            Hs=H + 2.0 * rho * deg[:, None, None] * eye[None],
        )
        return aux

    def init(self, aux, statics):
        N, p, d = aux["shape"]
        zeros = jnp.zeros((N, p, d), aux["dtype"])
        state = dict(x=zeros, alpha=zeros)
        if statics.get("ASYNC"):
            state["hist"] = jnp.zeros((statics["D"], N, p, d), aux["dtype"])
        return state

    def step(self, state, inp, aux, statics):
        x, alpha = state["x"], state["alpha"]
        A, deg, rho = aux["A"], aux["deg"], aux["rho"]
        if statics.get("ASYNC"):
            # Delayed-broadcast D-ADMM: dual-first from the PRE-update
            # iterate. The published age-1 value at delta = 0 IS x_k, so
            # alpha' accumulates exactly the synchronous dual residuals
            # rho (deg x_k - A x_k) and the degenerate async path
            # reproduces the synchronous sequence (DESIGN.md §13);
            # crashed agents (act = 0) freeze primal and dual.
            wslot, rslot, act = inp
            stale = self._published(state["hist"], rslot)
            nbr_sum = jnp.einsum("ij,jpd->ipd", A, stale)
            alpha_new = alpha + rho * (deg[:, None, None] * x - nbr_sum)
            rhs = (
                aux["rhs0"]
                + rho * (deg[:, None, None] * x + nbr_sum)
                - alpha_new
            )
            x_new = jnp.linalg.solve(aux["Hs"], rhs)
            gate = act[:, None, None] > 0
            x_new = jnp.where(gate, x_new, x)
            alpha = jnp.where(gate, alpha_new, alpha)
            hist = state["hist"].at[wslot].set(x_new)
            state = dict(x=x_new, alpha=alpha, hist=hist)
        else:
            nbr_sum = jnp.einsum("ij,jpd->ipd", A, x)
            rhs = (
                aux["rhs0"]
                + rho * (deg[:, None, None] * x + nbr_sum)
                - alpha
            )
            x_new = jnp.linalg.solve(aux["Hs"], rhs)
            nbr_sum_new = jnp.einsum("ij,jpd->ipd", A, x_new)
            alpha = alpha + rho * (deg[:, None, None] * x_new - nbr_sum_new)
            state = dict(x=x_new, alpha=alpha)
        return state, self.metrics(x_new, x_new.mean(0), aux)


class DGD(_GossipKernel):
    """Decentralized gradient descent [6] with Metropolis mixing."""

    name = "DGD"

    def config(self, case) -> GossipRun:
        return GossipRun(
            case.alpha, diminishing=True,
            timing=case.timing_model(), seed=case.seed,
        )

    def prepare(self, problem, net: Network, run: GossipRun, iters: int):
        steps = (
            run.param / np.sqrt(np.arange(1, iters + 1))
            if run.diminishing
            else np.full(iters, run.param)
        )
        dt = problem.O.dtype
        sim_time, extra, extra_statics = self._event_schedules(
            run, net, iters, dt
        )
        return Prepared(
            consts=_lsq_consts(problem, metropolis_weights(net)),
            steps=(steps.astype(dt),) + extra,
            statics=dict(name=self.name, iters=iters, **extra_statics),
            max_statics={},
            comm=np.cumsum(np.full(iters, 2.0 * net.E)),
            sim_time=sim_time,
        )

    def setup(self, consts, statics):
        O, T, W, x_star, O_test, T_test = consts
        aux = self.lsq_aux(O, T, x_star, O_test, T_test)
        aux["W"] = W
        return aux

    def init(self, aux, statics):
        state = dict(x=jnp.zeros(aux["shape"], aux["dtype"]))
        if statics.get("ASYNC"):
            N, p, d = aux["shape"]
            state["hist"] = jnp.zeros((statics["D"], N, p, d), aux["dtype"])
        return state

    def step(self, state, inp, aux, statics):
        x = state["x"]
        if statics.get("ASYNC"):
            alpha, wslot, rslot, act = inp
            # Mix stale published neighbor iterates; the gradient is at
            # the agent's own fresh iterate (DESIGN.md §13).
            mixed = jnp.einsum(
                "ij,jpd->ipd", aux["W"], self._published(state["hist"], rslot)
            )
            x_new = mixed - alpha * self._grad(aux, x)
            x_new = jnp.where(act[:, None, None] > 0, x_new, x)
            hist = state["hist"].at[wslot].set(x_new)
            state = dict(x=x_new, hist=hist)
        else:
            (alpha,) = inp
            x_new = jnp.einsum(
                "ij,jpd->ipd", aux["W"], x
            ) - alpha * self._grad(aux, x)
            state = dict(x=x_new)
        return state, self.metrics(x_new, x_new.mean(0), aux)


class EXTRA(_GossipKernel):
    """EXTRA [7]: exact first-order gossip with constant step size."""

    name = "EXTRA"
    _ages = 2  # reads publications one AND two rounds back

    def config(self, case) -> GossipRun:
        return GossipRun(
            case.alpha, timing=case.timing_model(), seed=case.seed
        )

    def prepare(self, problem, net: Network, run: GossipRun, iters: int):
        sim_time, extra, extra_statics = self._event_schedules(
            run, net, iters, problem.O.dtype
        )
        return Prepared(
            consts=_lsq_consts(problem, metropolis_weights(net), run.param),
            steps=extra,
            statics=dict(name=self.name, iters=iters, **extra_statics),
            max_statics={},
            comm=np.cumsum(np.full(iters, 2.0 * net.E)),
            sim_time=sim_time,
        )

    def setup(self, consts, statics):
        O, T, W, x_star, O_test, T_test, alpha = consts
        aux = self.lsq_aux(O, T, x_star, O_test, T_test)
        N = O.shape[0]
        eye = jnp.eye(N, dtype=O.dtype)
        aux.update(W=W, alpha=alpha, I_plus_W=eye + W, W_tilde=0.5 * (eye + W))
        return aux

    def init(self, aux, statics):
        x0 = jnp.zeros(aux["shape"], aux["dtype"])
        x1 = jnp.einsum("ij,jpd->ipd", aux["W"], x0) - aux[
            "alpha"
        ] * self._grad(aux, x0)
        state = dict(x_prev=x0, x=x1)
        if statics.get("ASYNC"):
            N, p, d = aux["shape"]
            # Slot D-1 holds x1 (the round-(-1) publication read at
            # delta = 0 in round 0); slot D-2 stays x0 = 0.
            hist = jnp.zeros((statics["D"], N, p, d), aux["dtype"])
            state["hist"] = hist.at[statics["D"] - 1].set(x1)
        return state

    def step(self, state, inp, aux, statics):
        x_prev, x_cur = state["x_prev"], state["x"]
        if statics.get("ASYNC"):
            wslot, rslot_prev, rslot, act = inp
            mix_cur = self._published(state["hist"], rslot)
            mix_prev = self._published(state["hist"], rslot_prev)
        else:
            mix_cur, mix_prev = x_cur, x_prev
        x_next = (
            jnp.einsum("ij,jpd->ipd", aux["I_plus_W"], mix_cur)
            - jnp.einsum("ij,jpd->ipd", aux["W_tilde"], mix_prev)
            - aux["alpha"] * (self._grad(aux, x_cur) - self._grad(aux, x_prev))
        )
        if statics.get("ASYNC"):
            gate = act[:, None, None] > 0
            x_next = jnp.where(gate, x_next, x_cur)
            # A frozen agent's recursion pair freezes with it.
            new_prev = jnp.where(gate, x_cur, x_prev)
            hist = state["hist"].at[wslot].set(x_next)
            state = dict(x_prev=new_prev, x=x_next, hist=hist)
        else:
            state = dict(x_prev=x_cur, x=x_next)
        return state, self.metrics(x_next, x_next.mean(0), aux)


D_ADMM_K = register(DADMM())
DGD_K = register(DGD())
EXTRA_K = register(EXTRA())
