"""Gossip baselines (D-ADMM, DGD, EXTRA) as MethodKernels (paper §V-A).

Every agent updates every iteration using all its neighbors — 2|E|
directed messages per iteration versus the incremental methods' single
token hop. All three consume full local gradients, as in the original
methods; the consensus model reported in metrics is the agent mean.

Simulated wall-clock: a round costs the slowest agent's compute plus its
serialized per-neighbor link transfers (`TimingModel.gossip_round_times`,
DESIGN.md §10), the synchronous-decentralized accounting in the style of
EXTRA-era analyses (arXiv 1503.08855) — so gossip traces live on the same
accuracy-vs-running-time axis as the paper's incremental methods.
Timing draws use the composite seed stream [4, seed] (disjoint from the
scalar-seeded ADMM schedule streams and privacy/quantization [2|3, seed]).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Network, metropolis_weights
from repro.core.problems import LeastSquaresProblem
from repro.core.timing import TimingModel

from .base import MethodKernel, Prepared, register

__all__ = [
    "GossipRun",
    "DADMM",
    "DGD",
    "EXTRA",
    "D_ADMM_K",
    "DGD_K",
    "EXTRA_K",
]


@dataclasses.dataclass(frozen=True)
class GossipRun:
    """Per-run config of a gossip baseline: step parameter + clock.

    ``param`` is rho for D-ADMM and alpha for DGD/EXTRA; ``seed`` drives
    the host-side timing draws (topology/data sampling stays with the
    problem, as everywhere else).
    """

    param: float
    diminishing: bool = False  # DGD: alpha_k = param / sqrt(k)
    timing: Optional[TimingModel] = None
    seed: int = 0


def _lsq_consts(problem: LeastSquaresProblem, mix: np.ndarray, *scalars):
    dt = problem.O.dtype
    return (
        problem.O,
        problem.T,
        mix.astype(dt),
        problem.x_star().astype(dt),
        problem.O_test,
        problem.T_test,
        *(np.asarray(s, dtype=dt) for s in scalars),
    )


class _GossipKernel(MethodKernel):
    """Shared shape/metric/timing plumbing for all-agents-per-step methods."""

    def static_signature(
        self, problem: LeastSquaresProblem, cfg, iters: int
    ) -> tuple:
        return (
            self.name,
            problem.N, problem.b, problem.p, problem.d,
            problem.O_test.shape[0], iters,
        )

    @staticmethod
    def _sim_time(run: GossipRun, net: Network, iters: int) -> np.ndarray:
        """Cumulative simulated seconds over gossip rounds (DESIGN.md §10)."""
        timing = run.timing or TimingModel()
        rng = np.random.default_rng([4, run.seed])
        return np.cumsum(timing.gossip_round_times(net, iters, rng))

    def _grad(self, aux, x):
        """Stacked full local gradients (N, p, d)."""
        O, T = aux["O"], aux["T"]
        return (
            jnp.einsum(
                "nbp,nbd->npd", O, jnp.einsum("nbp,npd->nbd", O, x) - T
            )
            / aux["b"]
        )

    def final(self, state, aux, statics):
        x = state["x"]
        return x, x.mean(0)


class DADMM(_GossipKernel):
    """Gossip decentralized consensus ADMM [14]/[9] (exact local solves)."""

    name = "D-ADMM"

    def config(self, case) -> GossipRun:
        return GossipRun(
            case.rho, timing=case.timing_model(), seed=case.seed
        )

    def prepare(self, problem, net: Network, run: GossipRun, iters: int):
        dt = problem.O.dtype
        consts = (
            problem.O,
            problem.T,
            net.adjacency.astype(dt),
            net.degree().astype(dt),
            problem.x_star().astype(dt),
            problem.O_test,
            problem.T_test,
            np.asarray(run.param, dtype=dt),
        )
        return Prepared(
            consts=consts,
            steps=(),
            statics=dict(name=self.name, iters=iters),
            max_statics={},
            comm=np.cumsum(np.full(iters, 2.0 * net.E)),
            sim_time=self._sim_time(run, net, iters),
        )

    def setup(self, consts, statics):
        O, T, A, deg, x_star, O_test, T_test, rho = consts
        aux = self.lsq_aux(O, T, x_star, O_test, T_test)
        N, b, p = O.shape
        H = jnp.einsum("nbp,nbq->npq", O, O) / b
        eye = jnp.eye(p, dtype=O.dtype)
        aux.update(
            A=A, deg=deg, rho=rho,
            rhs0=jnp.einsum("nbp,nbd->npd", O, T) / b,
            # Per-agent solve operator: (H_i + 2 rho d_i I)
            Hs=H + 2.0 * rho * deg[:, None, None] * eye[None],
        )
        return aux

    def init(self, aux, statics):
        N, p, d = aux["shape"]
        zeros = jnp.zeros((N, p, d), aux["dtype"])
        return dict(x=zeros, alpha=zeros)

    def step(self, state, inp, aux, statics):
        x, alpha = state["x"], state["alpha"]
        A, deg, rho = aux["A"], aux["deg"], aux["rho"]
        nbr_sum = jnp.einsum("ij,jpd->ipd", A, x)
        rhs = aux["rhs0"] + rho * (deg[:, None, None] * x + nbr_sum) - alpha
        x_new = jnp.linalg.solve(aux["Hs"], rhs)
        nbr_sum_new = jnp.einsum("ij,jpd->ipd", A, x_new)
        alpha = alpha + rho * (deg[:, None, None] * x_new - nbr_sum_new)
        state = dict(x=x_new, alpha=alpha)
        return state, self.metrics(x_new, x_new.mean(0), aux)


class DGD(_GossipKernel):
    """Decentralized gradient descent [6] with Metropolis mixing."""

    name = "DGD"

    def config(self, case) -> GossipRun:
        return GossipRun(
            case.alpha, diminishing=True,
            timing=case.timing_model(), seed=case.seed,
        )

    def prepare(self, problem, net: Network, run: GossipRun, iters: int):
        steps = (
            run.param / np.sqrt(np.arange(1, iters + 1))
            if run.diminishing
            else np.full(iters, run.param)
        )
        return Prepared(
            consts=_lsq_consts(problem, metropolis_weights(net)),
            steps=(steps.astype(problem.O.dtype),),
            statics=dict(name=self.name, iters=iters),
            max_statics={},
            comm=np.cumsum(np.full(iters, 2.0 * net.E)),
            sim_time=self._sim_time(run, net, iters),
        )

    def setup(self, consts, statics):
        O, T, W, x_star, O_test, T_test = consts
        aux = self.lsq_aux(O, T, x_star, O_test, T_test)
        aux["W"] = W
        return aux

    def init(self, aux, statics):
        return dict(x=jnp.zeros(aux["shape"], aux["dtype"]))

    def step(self, state, inp, aux, statics):
        (alpha,) = inp
        x = state["x"]
        x_new = jnp.einsum("ij,jpd->ipd", aux["W"], x) - alpha * self._grad(
            aux, x
        )
        return dict(x=x_new), self.metrics(x_new, x_new.mean(0), aux)


class EXTRA(_GossipKernel):
    """EXTRA [7]: exact first-order gossip with constant step size."""

    name = "EXTRA"

    def config(self, case) -> GossipRun:
        return GossipRun(
            case.alpha, timing=case.timing_model(), seed=case.seed
        )

    def prepare(self, problem, net: Network, run: GossipRun, iters: int):
        return Prepared(
            consts=_lsq_consts(problem, metropolis_weights(net), run.param),
            steps=(),
            statics=dict(name=self.name, iters=iters),
            max_statics={},
            comm=np.cumsum(np.full(iters, 2.0 * net.E)),
            sim_time=self._sim_time(run, net, iters),
        )

    def setup(self, consts, statics):
        O, T, W, x_star, O_test, T_test, alpha = consts
        aux = self.lsq_aux(O, T, x_star, O_test, T_test)
        N = O.shape[0]
        eye = jnp.eye(N, dtype=O.dtype)
        aux.update(W=W, alpha=alpha, I_plus_W=eye + W, W_tilde=0.5 * (eye + W))
        return aux

    def init(self, aux, statics):
        x0 = jnp.zeros(aux["shape"], aux["dtype"])
        x1 = jnp.einsum("ij,jpd->ipd", aux["W"], x0) - aux[
            "alpha"
        ] * self._grad(aux, x0)
        return dict(x_prev=x0, x=x1)

    def step(self, state, inp, aux, statics):
        x_prev, x_cur = state["x_prev"], state["x"]
        x_next = (
            jnp.einsum("ij,jpd->ipd", aux["I_plus_W"], x_cur)
            - jnp.einsum("ij,jpd->ipd", aux["W_tilde"], x_prev)
            - aux["alpha"] * (self._grad(aux, x_cur) - self._grad(aux, x_prev))
        )
        state = dict(x_prev=x_cur, x=x_next)
        return state, self.metrics(x_next, x_next.mean(0), aux)


D_ADMM_K = register(DADMM())
DGD_K = register(DGD())
EXTRA_K = register(EXTRA())
