"""Whisper-style encoder-decoder transformer backbone [arXiv:2212.04356].

Per the assignment carve-out, the audio frontend (mel-spectrogram + conv
feature extractor) is a STUB: ``input_specs`` provides precomputed frame
embeddings (B, encoder_positions, D) — this module implements the encoder
(bidirectional self-attention + learned positions) and the decoder (causal
self-attention + cross-attention) that consume them.

Serving: prefill runs encoder + decoder prompt and caches (a) the decoder
self-attention KV ring and (b) the per-layer cross-attention K/V projected
once from the encoder output (standard whisper serving trick).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    _z,
    _expand_kv,
    blocked_attention,
    decode_attention,
    layernorm,
    mlp_apply,
    naive_attention,
)


def init(cfg: ModelConfig, rng: jax.Array) -> dict:
    cfg.validate()
    dt = cfg.jnp_dtype
    D, V, F = cfg.d_model, cfg.vocab, cfg.d_ff
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    Le, Ld = cfg.encoder_layers, cfg.n_layers
    k = iter(jax.random.split(rng, 64))

    def w(key, *shape, scale=0.02):
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dt)

    def attn(n, prefix=""):
        return {
            f"{prefix}ln": jnp.ones((*n, D), dt),
            f"{prefix}ln_b": jnp.zeros((*n, D), dt),
            f"{prefix}wq": w(next(k), *n, D, H * hd),
            f"{prefix}wk": w(next(k), *n, D, KV * hd),
            f"{prefix}wv": w(next(k), *n, D, KV * hd),
            f"{prefix}wo": w(next(k), *n, H * hd, D, scale=0.005),
        }

    def mlp(n):
        return {
            "mln": jnp.ones((*n, D), dt),
            "mln_b": jnp.zeros((*n, D), dt),
            "w_in": w(next(k), *n, D, F),
            "w_out": w(next(k), *n, F, D, scale=0.005),
        }

    return {
        "enc_pos": w(next(k), cfg.encoder_positions, D, scale=0.01),
        "enc": {**attn((Le,)), **mlp((Le,))},
        "enc_norm": jnp.ones((D,), dt),
        "enc_norm_b": jnp.zeros((D,), dt),
        "embed": w(next(k), V, D),
        "dec_pos": w(next(k), 32768, D, scale=0.01),
        "dec": {**attn((Ld,)), **attn((Ld,), "x_"), **mlp((Ld,))},
        "dec_norm": jnp.ones((D,), dt),
        "dec_norm_b": jnp.zeros((D,), dt),
    }


def _mha(cfg, lp, xq, xkv, causal, prefix=""):
    B, Sq, D = xq.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (xq @ lp[f"{prefix}wq"]).reshape(B, Sq, H, hd)
    k_ = (xkv @ lp[f"{prefix}wk"]).reshape(B, xkv.shape[1], KV, hd)
    v = (xkv @ lp[f"{prefix}wv"]).reshape(B, xkv.shape[1], KV, hd)
    kx, vx = _expand_kv(k_, cfg.q_per_kv), _expand_kv(v, cfg.q_per_kv)
    if (
        causal
        and Sq == xkv.shape[1]
        and Sq > 1024
        and Sq % cfg.attn_block_q == 0
        and Sq % cfg.attn_block_kv == 0
    ):
        o = blocked_attention(
            q, kx, vx, causal=True,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
        )
    else:
        o = naive_attention(q, kx, vx, causal)
    return o.reshape(B, Sq, H * hd) @ lp[f"{prefix}wo"], (k_, v)


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, encoder_positions, D) stub embeddings."""
    from .layers import maybe_remat

    x = frames.astype(cfg.jnp_dtype) + params["enc_pos"][None]

    def block(x, lp):
        h = layernorm(x, lp["ln"], lp["ln_b"])
        o, _ = _mha(cfg, lp, h, h, causal=False)
        x = x + o
        h = layernorm(x, lp["mln"], lp["mln_b"])
        x = x + mlp_apply(h, lp, "gelu")
        return x, None

    x, _ = jax.lax.scan(maybe_remat(block, cfg.remat), x, params["enc"])
    return layernorm(x, params["enc_norm"], params["enc_norm_b"])


def _decoder_block(cfg, lp, x, enc_out, causal=True):
    h = layernorm(x, lp["ln"], lp["ln_b"])
    o, kv = _mha(cfg, lp, h, h, causal=causal)
    x = x + o
    h = layernorm(x, lp["x_ln"], lp["x_ln_b"])
    o, _ = _mha(cfg, lp, h, enc_out, causal=False, prefix="x_")
    x = x + o
    h = layernorm(x, lp["mln"], lp["mln_b"])
    x = x + mlp_apply(h, lp, "gelu")
    return x, kv


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, extra_embeds=None):
    """Training forward: extra_embeds = audio frames (B, T_enc, D)."""
    B, S = tokens.shape
    from .layers import maybe_remat

    enc_out = encode(cfg, params, extra_embeds)
    x = params["embed"][tokens] + params["dec_pos"][:S][None]

    def block(x, lp):
        x, _ = _decoder_block(cfg, lp, x, enc_out)
        return x, None

    x, _ = jax.lax.scan(maybe_remat(block, cfg.remat), x, params["dec"])
    x = layernorm(x, params["dec_norm"], params["dec_norm_b"])
    return x, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict):
    from .losses import lm_loss

    hidden, _ = forward(
        cfg, params, batch["tokens"], batch["extra_embeds"]
    )
    loss = lm_loss(
        hidden @ params["embed"].T, batch["labels"], batch.get("loss_weights")
    )
    return loss, {"nll": loss, "moe_aux": jnp.zeros((), jnp.float32)}


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, B: int, seq_len: int) -> dict:
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    dt = cfg.jnp_dtype
    Te = cfg.encoder_positions
    return {
        "k": jnp.zeros((L, B, seq_len, KV, hd), dt),
        "v": jnp.zeros((L, B, seq_len, KV, hd), dt),
        "xk": jnp.zeros((L, B, Te, KV, hd), dt),
        "xv": jnp.zeros((L, B, Te, KV, hd), dt),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    extra_embeds=None,
    extra_slots: int = 0,
):
    from .transformer import _to_ring

    B, S = tokens.shape
    C = S + extra_slots
    enc_out = encode(cfg, params, extra_embeds)
    x = params["embed"][tokens] + params["dec_pos"][:S][None]

    def block(x, lp):
        x, (k_, v) = _decoder_block(cfg, lp, x, enc_out)
        # Cross K/V computed once per layer for decode.
        KV, hd = cfg.n_kv_heads, cfg.d_head
        xk = (enc_out @ lp["x_wk"]).reshape(B, -1, KV, hd)
        xv = (enc_out @ lp["x_wv"]).reshape(B, -1, KV, hd)
        return x, (_to_ring(k_, S, C), _to_ring(v, S, C), xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(block, x, params["dec"])
    x = layernorm(x, params["dec_norm"], params["dec_norm_b"])
    logits = x[:, -1:] @ params["embed"].T
    cache = {
        "k": ks,
        "v": vs,
        "xk": xks,
        "xv": xvs,
        "len": jnp.asarray(S, jnp.int32),
    }
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, token: jax.Array):
    B = token.shape[0]
    C = cache["k"].shape[2]
    pos_t = cache["len"]
    slot = cache["len"] % jnp.asarray(C, jnp.int32)
    x = params["embed"][token] + params["dec_pos"][pos_t][None, None]
    n_valid = jnp.minimum(cache["len"] + 1, C)
    valid = jnp.broadcast_to(jnp.arange(C)[None] < n_valid, (B, C))
    Te = cache["xk"].shape[2]
    valid_x = jnp.ones((B, Te), bool)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    def block(x, layer):
        lp, kc, vc, xk, xv = layer
        h = layernorm(x, lp["ln"], lp["ln_b"])
        q = (h @ lp["wq"]).reshape(B, 1, H, hd)
        k_ = (h @ lp["wk"]).reshape(B, 1, KV, hd)
        v = (h @ lp["wv"]).reshape(B, 1, KV, hd)
        kc = jax.lax.dynamic_update_slice(kc, k_, (_z(slot), slot, _z(slot), _z(slot)))
        vc = jax.lax.dynamic_update_slice(vc, v, (_z(slot), slot, _z(slot), _z(slot)))
        o = decode_attention(q, kc, vc, valid)
        x = x + o.reshape(B, 1, H * hd) @ lp["wo"]
        # cross attention against cached encoder K/V
        h = layernorm(x, lp["x_ln"], lp["x_ln_b"])
        qx = (h @ lp["x_wq"]).reshape(B, 1, H, hd)
        o = decode_attention(qx, xk, xv, valid_x)
        x = x + o.reshape(B, 1, H * hd) @ lp["x_wo"]
        h = layernorm(x, lp["mln"], lp["mln_b"])
        x = x + mlp_apply(h, lp, "gelu")
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        block, x, (params["dec"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    x = layernorm(x, params["dec_norm"], params["dec_norm_b"])
    logits = x @ params["embed"].T
    new_cache = dict(cache, k=ks, v=vs, len=cache["len"] + 1)
    return logits, new_cache
