"""Uniform model API over all families.

``get_model(cfg)`` returns a `Model` whose members are pure functions:

  init(rng) -> params
  loss(params, batch) -> (loss, metrics)          # train step objective
  prefill(params, tokens[, extra_embeds]) -> (logits, cache)
  decode(params, cache, token) -> (logits, cache)
  init_cache(B, seq_len) -> cache
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

from . import mamba2, rglru, transformer, whisper
from .config import ModelConfig

__all__ = ["Model", "get_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    init_cache: Callable[..., Any]


_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": mamba2,
    "hybrid": rglru,
    "audio": whisper,
}


def get_model(cfg: ModelConfig) -> Model:
    mod = _FAMILIES[cfg.family]
    return Model(
        cfg=cfg,
        init=partial(mod.init, cfg),
        loss=partial(mod.loss_fn, cfg),
        prefill=partial(mod.prefill, cfg),
        decode=partial(mod.decode_step, cfg),
        init_cache=partial(mod.init_cache, cfg),
    )
