"""RecurrentGemma / Griffin hybrid: RG-LRU recurrence + local attention
[arXiv:2402.19427].

Layer pattern is 1 local-attention layer per ``attn_every`` layers
(RG uses 1:2 — pattern [rec, rec, attn] repeating). We scan over groups of
``attn_every`` layers (rec params stacked (G, R, ...), attn params (G, ...))
plus an unscanned tail of ``n_layers % attn_every`` recurrent layers, which
preserves the exact interleaving for any n_layers.

RG-LRU (per channel):
  r_t = sigmoid(x_t W_a + b_a)          recurrence gate
  i_t = sigmoid(x_t W_x + b_x)          input gate
  log a_t = -c * softplus(Lambda) * r_t          (c = 8)
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

computed with ``jax.lax.associative_scan`` (parallel prefix) over the
sequence — the TPU-native formulation of the recurrence (vs. the GPU
sequential kernel in the reference implementation).

Recurrent state + windowed KV cache are O(window) — serves long_500k.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    _z,
    _expand_kv,
    apply_rope,
    blocked_attention,
    decode_attention,
    mlp_apply,
    naive_attention,
    rmsnorm,
)

_C_RGLRU = 8.0


def _counts(cfg: ModelConfig):
    G = cfg.n_layers // cfg.attn_every
    R = cfg.attn_every - 1
    T = cfg.n_layers % cfg.attn_every  # tail recurrent layers
    return G, R, T


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------


def init(cfg: ModelConfig, rng: jax.Array) -> dict:
    cfg.validate()
    dt = cfg.jnp_dtype
    D, V, F = cfg.d_model, cfg.vocab, cfg.d_ff
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    Wl, cw = cfg.lru_width, cfg.conv_width
    G, R, T = _counts(cfg)
    k = iter(jax.random.split(rng, 64))

    def w(key, *shape, scale=0.02):
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dt)

    def mlp(n):
        return {
            "ln2": jnp.zeros((*n, D), dt),
            "w_gate": w(next(k), *n, D, F),
            "w_up": w(next(k), *n, D, F),
            "w_down": w(next(k), *n, F, D, scale=0.005),
        }

    def rec(n):
        return {
            "ln": jnp.zeros((*n, D), dt),
            "w_x": w(next(k), *n, D, Wl),
            "w_gate_in": w(next(k), *n, D, Wl),
            "conv_w": w(next(k), *n, cw, Wl, scale=0.2),
            "conv_b": jnp.zeros((*n, Wl), dt),
            "lru_wa": w(next(k), *n, Wl, Wl),
            "lru_ba": jnp.full((*n, Wl), 2.0, jnp.float32),
            "lru_wx": w(next(k), *n, Wl, Wl),
            "lru_bx": jnp.zeros((*n, Wl), jnp.float32),
            "lambda": jnp.full((*n, Wl), 1.0, jnp.float32),
            "w_out": w(next(k), *n, Wl, D, scale=0.005),
            **mlp(n),
        }

    def attn(n):
        return {
            "ln": jnp.zeros((*n, D), dt),
            "wq": w(next(k), *n, D, H * hd),
            "wk": w(next(k), *n, D, KV * hd),
            "wv": w(next(k), *n, D, KV * hd),
            "wo": w(next(k), *n, H * hd, D, scale=0.005),
            **mlp(n),
        }

    params = {
        "embed": w(next(k), V, D),
        "rec": rec((G, R)),
        "attn": attn((G,)),
        "final_norm": jnp.zeros((D,), dt),
    }
    if T:
        params["tail_rec"] = rec((T,))
    if not cfg.tie_embeddings:
        params["lm_head"] = w(next(k), D, V)
    return params


# --------------------------------------------------------------------------
# RG-LRU
# --------------------------------------------------------------------------


def _gates(lp, x):  # x (B, S, Wl)
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ lp["lru_wa"].astype(jnp.float32) + lp["lru_ba"])
    i = jax.nn.sigmoid(xf @ lp["lru_wx"].astype(jnp.float32) + lp["lru_bx"])
    log_a = -_C_RGLRU * jax.nn.softplus(lp["lambda"]) * r  # (B,S,Wl)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, b


def rglru_seq(lp: dict, x: jax.Array, h0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Parallel linear recurrence h_t = a_t h_{t-1} + b_t via associative scan.

    x: (B, S, Wl); h0: (B, Wl) carried state. Returns (h_seq, h_last)."""
    a, b = _gates(lp, x)
    # Fold the initial state into the first step: b_1 += a_1 * h0.
    b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hs.astype(x.dtype), hs[:, -1]


def rglru_step(lp: dict, x: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. x: (B, 1, Wl), h: (B, Wl) f32."""
    a, b = _gates(lp, x)
    h_new = a[:, 0] * h + b[:, 0]
    return h_new.astype(x.dtype)[:, None], h_new


def _causal_conv(seq: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    B, S, C = seq.shape
    W = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C,
    )
    return (out + b.astype(jnp.float32)).astype(seq.dtype)


# --------------------------------------------------------------------------
# Blocks (full sequence)
# --------------------------------------------------------------------------


def _rec_block_seq(cfg, lp, x, h0=None):
    B, S, D = x.shape
    h = rmsnorm(x, lp["ln"])
    gate = jax.nn.gelu((h @ lp["w_gate_in"]).astype(jnp.float32)).astype(x.dtype)
    xb = h @ lp["w_x"]
    xb = _causal_conv(xb, lp["conv_w"], lp["conv_b"])
    if h0 is None:
        h0 = jnp.zeros((B, cfg.lru_width), jnp.float32)
    if cfg.ssm_impl == "pallas":
        from repro.kernels import rglru_scan as _rglru

        a, bb = _gates(lp, xb)
        hs, h_last = _rglru(a, bb, h0)
        ys = hs.astype(xb.dtype)
    else:
        ys, h_last = rglru_seq(lp, xb, h0)
    out = (ys * gate) @ lp["w_out"]
    x = x + out
    # MLP
    h2 = rmsnorm(x, lp["ln2"])
    x = x + mlp_apply(h2, lp, "geglu")
    return x, h_last


def _attn_block_seq(cfg, lp, x):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    h = rmsnorm(x, lp["ln"])
    q = (h @ lp["wq"]).reshape(B, S, H, hd)
    k_ = (h @ lp["wk"]).reshape(B, S, KV, hd)
    v = (h @ lp["wv"]).reshape(B, S, KV, hd)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    q = apply_rope(q, pos, cfg.rope_theta)
    k_ = apply_rope(k_, pos, cfg.rope_theta)
    kx, vx = _expand_kv(k_, cfg.q_per_kv), _expand_kv(v, cfg.q_per_kv)
    if S > 1024 and S % cfg.attn_block_q == 0 and S % cfg.attn_block_kv == 0:
        o = blocked_attention(
            q, kx, vx, causal=True, window=cfg.sliding_window,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
        )
    else:
        o = naive_attention(q, kx, vx, causal=True, window=cfg.sliding_window)
    x = x + o.reshape(B, S, H * hd) @ lp["wo"]
    h2 = rmsnorm(x, lp["ln2"])
    x = x + mlp_apply(h2, lp, "geglu")
    return x, (k_, v)


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, extra_embeds=None):
    G, R, T = _counts(cfg)
    x = params["embed"][tokens]

    def group(x, gp):
        rec_p, attn_p = gp
        for r in range(R):
            lp = jax.tree.map(lambda a: a[r], rec_p)
            x, _ = _rec_block_seq(cfg, lp, x)
        x, _ = _attn_block_seq(cfg, attn_p, x)
        return x, None

    from .layers import maybe_remat

    x, _ = jax.lax.scan(
        maybe_remat(group, cfg.remat), x, (params["rec"], params["attn"])
    )
    for t in range(T):
        lp = jax.tree.map(lambda a: a[t], params["tail_rec"])
        x, _ = _rec_block_seq(cfg, lp, x)
    x = rmsnorm(x, params["final_norm"])
    return x, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict):
    from .losses import lm_loss

    hidden, _ = forward(cfg, params, batch["tokens"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    loss = lm_loss(hidden @ head, batch["labels"], batch.get("loss_weights"))
    return loss, {"nll": loss, "moe_aux": jnp.zeros((), jnp.float32)}


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, B: int, seq_len: int) -> dict:
    G, R, T = _counts(cfg)
    Wl, cw, hd, KV = cfg.lru_width, cfg.conv_width, cfg.d_head, cfg.n_kv_heads
    C = min(seq_len, cfg.sliding_window or seq_len)
    dt = cfg.jnp_dtype
    cache = {
        "lru": jnp.zeros((G, R, B, Wl), jnp.float32),
        "conv": jnp.zeros((G, R, B, cw - 1, Wl), dt),
        "k": jnp.zeros((G, B, C, KV, hd), dt),
        "v": jnp.zeros((G, B, C, KV, hd), dt),
        "len": jnp.zeros((), jnp.int32),
    }
    if T:
        cache["tail_lru"] = jnp.zeros((T, B, Wl), jnp.float32)
        cache["tail_conv"] = jnp.zeros((T, B, cw - 1, Wl), dt)
    return cache


def _rec_block_step(cfg, lp, x, h_lru, conv_tail):
    """Decode one token through a recurrent block."""
    h = rmsnorm(x, lp["ln"])
    gate = jax.nn.gelu((h @ lp["w_gate_in"]).astype(jnp.float32)).astype(x.dtype)
    xb = h @ lp["w_x"]  # (B, 1, Wl)
    window = jnp.concatenate([conv_tail, xb], axis=1)  # (B, cw, Wl)
    conv = jnp.einsum(
        "bwc,wc->bc", window.astype(jnp.float32), lp["conv_w"].astype(jnp.float32)
    ) + lp["conv_b"].astype(jnp.float32)
    xb = conv[:, None].astype(x.dtype)
    ys, h_new = rglru_step(lp, xb, h_lru)
    x = x + (ys * gate) @ lp["w_out"]
    x = x + mlp_apply(rmsnorm(x, lp["ln2"]), lp, "geglu")
    return x, h_new, window[:, 1:]


def _attn_block_step(cfg, lp, x, kc, vc, slot, pos_t, valid):
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    h = rmsnorm(x, lp["ln"])
    q = (h @ lp["wq"]).reshape(B, 1, H, hd)
    k_ = (h @ lp["wk"]).reshape(B, 1, KV, hd)
    v = (h @ lp["wv"]).reshape(B, 1, KV, hd)
    pos = jnp.broadcast_to(pos_t[None, None], (B, 1)).astype(jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta)
    k_ = apply_rope(k_, pos, cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice(kc, k_, (_z(slot), slot, _z(slot), _z(slot)))
    vc = jax.lax.dynamic_update_slice(vc, v, (_z(slot), slot, _z(slot), _z(slot)))
    o = decode_attention(q, kc, vc, valid)
    x = x + o.reshape(B, 1, H * hd) @ lp["wo"]
    x = x + mlp_apply(rmsnorm(x, lp["ln2"]), lp, "geglu")
    return x, kc, vc


def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    extra_embeds=None,
    extra_slots: int = 0,
):
    from .transformer import _to_ring

    G, R, T = _counts(cfg)
    B, S = tokens.shape
    cw = cfg.conv_width
    C = min(S + extra_slots, cfg.sliding_window or (S + extra_slots))
    x = params["embed"][tokens]

    def group(x, gp):
        rec_p, attn_p = gp
        lrus, convs = [], []
        for r in range(R):
            lp = jax.tree.map(lambda a: a[r], rec_p)
            # conv tail must be captured pre-conv: recompute branch input
            h = rmsnorm(x, lp["ln"])
            xb_raw = h @ lp["w_x"]
            x, h_last = _rec_block_seq(cfg, lp, x)
            lrus.append(h_last)
            convs.append(xb_raw[:, S - (cw - 1) :])
        x, (k_, v) = _attn_block_seq(cfg, attn_p, x)
        return x, (
            jnp.stack(lrus),
            jnp.stack(convs),
            _to_ring(k_, S, C),
            _to_ring(v, S, C),
        )

    x, (lru, conv, ks, vs) = jax.lax.scan(group, x, (params["rec"], params["attn"]))
    cache = {
        "lru": lru,
        "conv": conv,
        "k": ks,
        "v": vs,
        "len": jnp.asarray(S, jnp.int32),
    }
    if T:
        t_lru, t_conv = [], []
        for t in range(T):
            lp = jax.tree.map(lambda a: a[t], params["tail_rec"])
            h = rmsnorm(x, lp["ln"])
            xb_raw = h @ lp["w_x"]
            x, h_last = _rec_block_seq(cfg, lp, x)
            t_lru.append(h_last)
            t_conv.append(xb_raw[:, S - (cw - 1) :])
        cache["tail_lru"] = jnp.stack(t_lru)
        cache["tail_conv"] = jnp.stack(t_conv)
    x = rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x[:, -1:] @ head, cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, token: jax.Array):
    G, R, T = _counts(cfg)
    B = token.shape[0]
    C = cache["k"].shape[2]
    x = params["embed"][token]
    pos_t = cache["len"]
    slot = cache["len"] % jnp.asarray(C, jnp.int32)
    n_valid = jnp.minimum(cache["len"] + 1, C)
    valid = jnp.broadcast_to(jnp.arange(C)[None] < n_valid, (B, C))

    def group(x, layer):
        rec_p, attn_p, lru, conv, kc, vc = layer
        lrus, convs = [], []
        for r in range(R):
            lp = jax.tree.map(lambda a: a[r], rec_p)
            x, h_new, c_new = _rec_block_step(cfg, lp, x, lru[r], conv[r])
            lrus.append(h_new)
            convs.append(c_new)
        x, kc, vc = _attn_block_step(cfg, attn_p, x, kc, vc, slot, pos_t, valid)
        return x, (jnp.stack(lrus), jnp.stack(convs), kc, vc)

    x, (lru, conv, ks, vs) = jax.lax.scan(
        group,
        x,
        (params["rec"], params["attn"], cache["lru"], cache["conv"], cache["k"], cache["v"]),
    )
    new_cache = {
        "lru": lru,
        "conv": conv,
        "k": ks,
        "v": vs,
        "len": cache["len"] + 1,
    }
    if T:
        t_lru, t_conv = [], []
        for t in range(T):
            lp = jax.tree.map(lambda a: a[t], params["tail_rec"])
            x, h_new, c_new = _rec_block_step(
                cfg, lp, x, cache["tail_lru"][t], cache["tail_conv"][t]
            )
            t_lru.append(h_new)
            t_conv.append(c_new)
        new_cache["tail_lru"] = jnp.stack(t_lru)
        new_cache["tail_conv"] = jnp.stack(t_conv)
    x = rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_cache
