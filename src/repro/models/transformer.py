"""Decoder-only transformer LM covering dense / MoE / VLM-backbone configs.

Families served: llama3-405b, internlm2-20b, qwen3-0.6b, stablelm-1.6b
(dense), mixtral-8x22b, phi3.5-moe (MoE), qwen2-vl-72b (VLM backbone with a
vision-stub prefix). Layers are parameter-stacked and applied with
``lax.scan`` so a 126-layer model lowers to a compact HLO (critical for the
512-device dry-run on one host).

API (all pure functions of (cfg, params, ...)):
  init(cfg, rng)                           -> params
  loss_fn(cfg, params, batch)              -> (loss, metrics)
  prefill(cfg, params, tokens, ...)        -> (logits_last, cache)
  decode_step(cfg, params, cache, token)   -> (logits, cache)

Cache layout: dict(k=(L, B, C, KV, hd), v=..., len=scalar int32) with
C = min(seq_len, sliding_window). The cache is a ring buffer indexed by
slot = position % C, so decode writes at len % C and prefill rolls its tail
accordingly; validity is count-based (min(len+1, C) slots live).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    _z,
    apply_rope,
    blocked_attention,
    decode_attention,
    layernorm,
    mlp_apply,
    moe_apply,
    naive_attention,
    rmsnorm,
    _expand_kv,
)

# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------


def init(cfg: ModelConfig, rng: jax.Array) -> dict:
    cfg.validate()
    dt = cfg.jnp_dtype
    D, V, L, F = cfg.d_model, cfg.vocab, cfg.n_layers, cfg.d_ff
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    keys = iter(jax.random.split(rng, 32))

    def w(key, *shape, scale=0.02):
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dt)

    layers = {
        "ln1": jnp.zeros((L, D), dt),
        "ln2": jnp.zeros((L, D), dt),
        "wq": w(next(keys), L, D, H * hd),
        "wk": w(next(keys), L, D, KV * hd),
        "wv": w(next(keys), L, D, KV * hd),
        "wo": w(next(keys), L, H * hd, D, scale=0.02 / max(L, 1) ** 0.5),
    }
    if cfg.norm == "layernorm":
        layers["ln1_b"] = jnp.zeros((L, D), dt)
        layers["ln2_b"] = jnp.zeros((L, D), dt)
    if cfg.qk_norm:
        layers["q_norm"] = jnp.zeros((L, hd), dt)
        layers["k_norm"] = jnp.zeros((L, hd), dt)
    if cfg.family == "moe":
        E = cfg.n_experts
        layers["router"] = w(next(keys), L, D, E)
        layers["w_gate"] = w(next(keys), L, E, D, F)
        layers["w_up"] = w(next(keys), L, E, D, F)
        layers["w_down"] = w(next(keys), L, E, F, D, scale=0.02 / max(L, 1) ** 0.5)
    else:
        layers["w_gate"] = w(next(keys), L, D, F)
        layers["w_up"] = w(next(keys), L, D, F)
        layers["w_down"] = w(next(keys), L, F, D, scale=0.02 / max(L, 1) ** 0.5)

    params = {
        "embed": w(next(keys), V, D),
        "layers": layers,
        "final_norm": jnp.zeros((D,), dt),
    }
    if cfg.norm == "layernorm":
        params["final_norm_b"] = jnp.zeros((D,), dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = w(next(keys), D, V)
    if cfg.modality == "vision_stub":
        # Projector from the (stub) vision encoder to d_model.
        params["vis_proj"] = w(next(keys), D, D)
    return params


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------


def _norm(cfg, x, scale, bias=None):
    if cfg.norm == "layernorm":
        return layernorm(x, scale, bias)
    return rmsnorm(x, scale)


def _positions(cfg: ModelConfig, B: int, S: int, offset=0) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.mrope_sections is not None:
        # Text / stub tokens: all three M-RoPE channels share the position id.
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def _attn_qkv(cfg, lp, h, positions):
    B, S, D = h.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (h @ lp["wq"]).reshape(B, S, H, hd)
    k = (h @ lp["wk"]).reshape(B, S, KV, hd)
    v = (h @ lp["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, lp["q_norm"])
        k = rmsnorm(k, lp["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction, cfg.mrope_sections)
    return q, k, v


def _self_attention(cfg: ModelConfig, lp: dict, x: jax.Array, positions) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Pre-norm attention sub-block. Returns (residual_out, (k, v))."""
    B, S, D = x.shape
    h = _norm(cfg, x, lp["ln1"], lp.get("ln1_b"))
    q, k, v = _attn_qkv(cfg, lp, h, positions)
    if cfg.attn_impl == "pallas":
        # Pallas flash-attention kernel: GQA handled by the kernel's K/V
        # index maps (no materialized head expansion).
        from repro.kernels import flash_attention as _flash

        o = _flash(
            q, k, v, causal=True, window=cfg.sliding_window,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
        )
    else:
        kx = _expand_kv(k, cfg.q_per_kv)
        vx = _expand_kv(v, cfg.q_per_kv)
        if S > 1024 and S % cfg.attn_block_q == 0 and S % cfg.attn_block_kv == 0:
            o = blocked_attention(
                q, kx, vx, causal=True, window=cfg.sliding_window,
                block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
            )
        else:
            o = naive_attention(q, kx, vx, causal=True, window=cfg.sliding_window)
    o = o.reshape(B, S, cfg.n_heads * cfg.d_head) @ lp["wo"]
    return x + o, (k, v)


def _ffn(cfg: ModelConfig, lp: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    B, S, D = x.shape
    h = _norm(cfg, x, lp["ln2"], lp.get("ln2_b"))
    if cfg.family == "moe":
        out, aux = moe_apply(
            h.reshape(B * S, D),
            {k: lp[k] for k in ("router", "w_gate", "w_up", "w_down")},
            cfg.n_experts,
            cfg.experts_per_token,
            cfg.capacity_factor,
            act=cfg.mlp_act,
            groups=cfg.moe_groups,
            shard_axis=cfg.moe_shard_axis,
        )
        return x + out.reshape(B, S, D), aux
    out = mlp_apply(h, lp, cfg.mlp_act)
    return x + out, jnp.zeros((), jnp.float32)


def _embed(cfg: ModelConfig, params: dict, tokens: jax.Array, extra_embeds=None) -> jax.Array:
    x = params["embed"][tokens]  # (B, S, D)
    if extra_embeds is not None:
        # Modality stub: precomputed patch/frame embeddings replace the
        # leading positions (assignment carve-out; see DESIGN.md §4).
        ee = extra_embeds.astype(x.dtype)
        if "vis_proj" in params:
            ee = ee @ params["vis_proj"]
        Sv = ee.shape[1]
        x = jnp.concatenate([ee, x[:, Sv:]], axis=1)
    return x


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # (B, S) int32
    extra_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward; returns (hidden (B,S,D), moe_aux scalar)."""
    B, S = tokens.shape
    x = _embed(cfg, params, tokens, extra_embeds)
    positions = _positions(cfg, B, S)

    def block(x, lp):
        x, _ = _self_attention(cfg, lp, x, positions)
        x, aux = _ffn(cfg, lp, x)
        return x, aux

    from .layers import maybe_remat

    x, auxs = jax.lax.scan(maybe_remat(block, cfg.remat), x, params["layers"])
    x = _norm(cfg, x, params["final_norm"], params.get("final_norm_b"))
    return x, auxs.sum()


def logits_from_hidden(cfg: ModelConfig, params: dict, hidden: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return hidden @ head


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> Tuple[jax.Array, dict]:
    """Causal LM loss. batch: tokens (B,S), labels (B,S) (-100 = ignore),
    optionally extra_embeds (stub modalities) and loss_weights (B,) per-row
    weights (coded-gradient path, see repro.models.losses)."""
    from .losses import lm_loss

    hidden, aux = forward(
        cfg, params, batch["tokens"], batch.get("extra_embeds")
    )
    logits = logits_from_hidden(cfg, params, hidden)
    loss = lm_loss(logits, batch["labels"], batch.get("loss_weights"))
    total = loss + cfg.router_aux_weight * aux
    return total, {"nll": loss, "moe_aux": aux}


# --------------------------------------------------------------------------
# Serving: prefill + single-token decode
# --------------------------------------------------------------------------


def cache_capacity(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: ModelConfig, B: int, seq_len: int) -> dict:
    C = cache_capacity(cfg, seq_len)
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    dt = cfg.jnp_dtype
    return {
        "k": jnp.zeros((L, B, C, KV, hd), dt),
        "v": jnp.zeros((L, B, C, KV, hd), dt),
        "len": jnp.zeros((), jnp.int32),  # tokens seen; write slot = len % C
    }


def _to_ring(k: jax.Array, S: int, C: int) -> jax.Array:
    """(B, S, ...) prefill K/V -> (B, C, ...) ring cache with slot = pos % C.

    C > S: pad with empty slots at the end (headroom for decode);
    C <= S: keep the last C entries, rolled into ring position."""
    if C >= S:
        pad = [(0, 0)] * k.ndim
        pad[1] = (0, C - S)
        return jnp.pad(k, pad)
    return jnp.roll(k[:, S - C :], S % C, axis=1)


def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # (B, S)
    extra_embeds: Optional[jax.Array] = None,
    extra_slots: int = 0,  # decode headroom reserved in the cache
) -> Tuple[jax.Array, dict]:
    """Run the full prompt, return last-position logits + the KV cache."""
    B, S = tokens.shape
    x = _embed(cfg, params, tokens, extra_embeds)
    positions = _positions(cfg, B, S)
    C = cache_capacity(cfg, S + extra_slots)

    def block(x, lp):
        x, (k, v) = _self_attention(cfg, lp, x, positions)
        x, _ = _ffn(cfg, lp, x)
        return x, (_to_ring(k, S, C), _to_ring(v, S, C))

    x, (ks, vs) = jax.lax.scan(block, x, params["layers"])
    x = _norm(cfg, x, params["final_norm"], params.get("final_norm_b"))
    logits = logits_from_hidden(cfg, params, x[:, -1:])
    cache = {"k": ks, "v": vs, "len": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    token: jax.Array,  # (B, 1) int32
) -> Tuple[jax.Array, dict]:
    """One decode step against the KV cache (ring-buffered if windowed)."""
    B = token.shape[0]
    x = _embed(cfg, params, token)
    C = cache["k"].shape[2]
    pos_t = cache["len"]  # true position id of this token
    slot = cache["len"] % jnp.asarray(C, jnp.int32)
    positions = jnp.broadcast_to(pos_t[None, None], (B, 1)).astype(jnp.int32)
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(positions[None], (3, B, 1))
    n_valid = jnp.minimum(cache["len"] + 1, C)
    valid = jnp.arange(C)[None, :] < n_valid
    valid = jnp.broadcast_to(valid, (B, C))

    def block(x, layer):
        lp, kc, vc = layer
        h = _norm(cfg, x, lp["ln1"], lp.get("ln1_b"))
        q, k, v = _attn_qkv(cfg, lp, h, positions)
        kc = jax.lax.dynamic_update_slice(kc, k, (_z(slot), slot, _z(slot), _z(slot)))
        vc = jax.lax.dynamic_update_slice(vc, v, (_z(slot), slot, _z(slot), _z(slot)))
        o = decode_attention(q, kc, vc, valid)
        o = o.reshape(B, 1, cfg.n_heads * cfg.d_head) @ lp["wo"]
        x = x + o
        x, _ = _ffn(cfg, lp, x)
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        block, x, (params["layers"], cache["k"], cache["v"])
    )
    x = _norm(cfg, x, params["final_norm"], params.get("final_norm_b"))
    logits = logits_from_hidden(cfg, params, x)
    new_cache = {"k": ks, "v": vs, "len": cache["len"] + 1}
    return logits, new_cache
