"""Mamba-2 (SSD, state-space duality) language model [arXiv:2405.21060].

Block = in_proj -> causal depthwise conv (x, B, C) -> SSD -> gated RMSNorm
-> out_proj, with the chunked SSD algorithm (intra-chunk dual/quadratic form
+ inter-chunk state recurrence via ``lax.scan``) for training/prefill and a
constant-memory recurrent update for decode.

Shapes: B batch, S seq, D d_model, di = expand*D inner, H ssm heads,
P = di/H head dim, N ssm state, G groups (=1), Q chunk length.

State cache: dict(ssm=(L, B, H, P, N) f32, conv=(L, B, W-1, conv_dim),
len=scalar). The SSD state is the analogue of a KV cache with O(1) size —
this is why mamba2 serves the long_500k shape.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import rmsnorm

# --------------------------------------------------------------------------


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim or di // H
    N = cfg.ssm_state
    conv_dim = di + 2 * N  # x, B, C pass through the conv (G=1)
    return di, H, P, N, conv_dim


def init(cfg: ModelConfig, rng: jax.Array) -> dict:
    cfg.validate()
    dt = cfg.jnp_dtype
    D, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    di, H, P, N, conv_dim = _dims(cfg)
    W = cfg.conv_width
    keys = iter(jax.random.split(rng, 16))

    def w(key, *shape, scale=0.02):
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dt)

    # in_proj packs (z, x, B, C, dt): di + di + N + N + H columns.
    layers = {
        "ln": jnp.zeros((L, D), dt),
        "w_in": w(next(keys), L, D, 2 * di + 2 * N + H),
        "conv_w": w(next(keys), L, W, conv_dim, scale=0.2),
        "conv_b": jnp.zeros((L, conv_dim), dt),
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.linspace(1.0, 16.0, H), (L, H))
        ).astype(jnp.float32),
        "dt_bias": jnp.zeros((L, H), jnp.float32),
        "D_skip": jnp.ones((L, H), jnp.float32),
        "norm": jnp.zeros((L, di), dt),
        "w_out": w(next(keys), L, di, D, scale=0.02 / max(L, 1) ** 0.5),
    }
    params = {
        "embed": w(next(keys), V, D),
        "layers": layers,
        "final_norm": jnp.zeros((D,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = w(next(keys), D, V)
    return params


# --------------------------------------------------------------------------
# SSD core
# --------------------------------------------------------------------------


def _segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular pairwise cumulative sums: out[..., i, j] =
    sum_{j < t <= i} a[..., t], -inf above the diagonal."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P) — pre-multiplied by nothing; dt applied here
    dt: jax.Array,  # (B, S, H) f32, post-softplus
    A: jax.Array,  # (H,) f32, negative
    Bm: jax.Array,  # (B, S, N) (G=1)
    Cm: jax.Array,  # (B, S, N)
    chunk: int,
    h0: Optional[jax.Array] = None,  # (B, H, P, N) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD (Mamba-2 alg.): returns (y (B,S,H,P), final state)."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = chunk
    S_orig = S
    if S % Q != 0:
        # Pad to a chunk multiple with dt = 0 steps: decay exp(0·A) = 1 and
        # input x·dt = 0, so padded positions are identities on the state.
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    a = dt * A[None, None, :]  # (B, S, H) log-decay per step
    xdt = (x.astype(jnp.float32) * dt[..., None]).astype(jnp.float32)

    # reshape into chunks: (nc, B, Q, ...)
    def chunked(t, feat_shape):
        return t.reshape(B_, nc, Q, *feat_shape).transpose(1, 0, 2, *(i + 3 for i in range(len(feat_shape))))

    ac = a.reshape(B_, nc, Q, H).transpose(1, 0, 2, 3)  # (nc,B,Q,H)
    xc = xdt.reshape(B_, nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    Bc = Bm.astype(jnp.float32).reshape(B_, nc, Q, N).transpose(1, 0, 2, 3)
    Cc = Cm.astype(jnp.float32).reshape(B_, nc, Q, N).transpose(1, 0, 2, 3)

    def per_chunk(carry, inp):
        h = carry  # (B, H, P, N)
        a_, x_, B_in, C_in = inp  # (B,Q,H), (B,Q,H,P), (B,Q,N), (B,Q,N)
        a_t = a_.transpose(0, 2, 1)  # (B, H, Q)
        cum = jnp.cumsum(a_t, axis=-1)  # (B, H, Q)
        # Intra-chunk (dual quadratic form): Lmat (B,H,Q,Q)
        Lmat = jnp.exp(_segsum(a_t))
        scores = jnp.einsum("bin,bjn->bij", C_in, B_in)  # (B,Q,Q)
        y_intra = jnp.einsum(
            "bij,bhij,bjhp->bihp", scores, Lmat, x_
        )
        # Contribution of the carried-in state: y_inter[i] = C_i h * exp(cum_i)
        y_inter = jnp.einsum(
            "bin,bhpn,bhi->bihp", C_in, h, jnp.exp(cum)
        )
        # Chunk-final state: h' = h * exp(cum_Q) + sum_j exp(cum_Q - cum_j) B_j x_j
        decay_out = jnp.exp(cum[..., -1:] - cum)  # (B, H, Q)
        h_new = h * jnp.exp(cum[..., -1])[..., None, None] + jnp.einsum(
            "bjn,bhj,bjhp->bhpn", B_in, decay_out, x_
        )
        return h_new, y_intra + y_inter

    h0 = (
        jnp.zeros((B_, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )
    h_final, yc = jax.lax.scan(per_chunk, h0, (ac, xc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B_, S, H, P)
    return y[:, :S_orig], h_final


def ssd_decode(
    x: jax.Array,  # (B, 1, H, P)
    dt: jax.Array,  # (B, 1, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, 1, N)
    Cm: jax.Array,  # (B, 1, N)
    h: jax.Array,  # (B, H, P, N) f32
) -> Tuple[jax.Array, jax.Array]:
    """Single-step recurrence: h = exp(dt*A) h + (dt*x) outer B; y = C.h"""
    a = jnp.exp(dt[:, 0, :, None, None] * A[None, :, None, None])
    xdt = (x[:, 0].astype(jnp.float32) * dt[:, 0, :, None])
    h_new = a * h + jnp.einsum("bhp,bn->bhpn", xdt, Bm[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cm[:, 0].astype(jnp.float32))
    return y[:, None], h_new


# --------------------------------------------------------------------------
# Block plumbing
# --------------------------------------------------------------------------


def _causal_conv(seq: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. seq (B, S, C), w (W, C)."""
    B, S, C = seq.shape
    W = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],  # (W, 1, C)
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C,
    )
    return (out + b.astype(jnp.float32)).astype(seq.dtype)


def _split_proj(cfg, proj):
    di, H, P, N, conv_dim = _dims(cfg)
    z, xBC, dt = jnp.split(proj, [di, di + conv_dim], axis=-1)
    return z, xBC, dt  # xBC = (x | B | C) pre-conv


def _block_seq(cfg: ModelConfig, lp: dict, u: jax.Array) -> jax.Array:
    """Full-sequence mamba2 block (pre-norm residual)."""
    di, H, P, N, conv_dim = _dims(cfg)
    B_, S, D = u.shape
    h = rmsnorm(u, lp["ln"])
    z, xBC, dt_raw = _split_proj(cfg, h @ lp["w_in"])
    xBC = jax.nn.silu(_causal_conv(xBC, lp["conv_w"], lp["conv_b"]))
    x, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + lp["dt_bias"][None, None]
    )
    A = -jnp.exp(lp["A_log"])  # (H,)
    if cfg.ssm_impl == "pallas":
        from repro.kernels import ssd_scan as _ssd

        y, _ = _ssd(
            x.reshape(B_, S, H, P), dt, A, Bm, Cm, chunk=cfg.ssm_chunk
        )
    else:
        y, _ = ssd_chunked(
            x.reshape(B_, S, H, P), dt, A, Bm, Cm, cfg.ssm_chunk
        )
    y = y + lp["D_skip"][None, None, :, None] * x.reshape(B_, S, H, P).astype(jnp.float32)
    y = y.reshape(B_, S, di).astype(u.dtype)
    y = rmsnorm(y, lp["norm"]) * jax.nn.silu(z)
    return u + y @ lp["w_out"]


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, extra_embeds=None) -> Tuple[jax.Array, jax.Array]:
    from .layers import maybe_remat

    x = params["embed"][tokens]

    def block(x, lp):
        return _block_seq(cfg, lp, x), None

    x, _ = jax.lax.scan(maybe_remat(block, cfg.remat), x, params["layers"])
    x = rmsnorm(x, params["final_norm"])
    return x, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict):
    from .losses import lm_loss

    hidden, _ = forward(cfg, params, batch["tokens"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    loss = lm_loss(hidden @ head, batch["labels"], batch.get("loss_weights"))
    return loss, {"nll": loss, "moe_aux": jnp.zeros((), jnp.float32)}


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, B: int, seq_len: int) -> dict:
    """SSM state + conv tail — O(1) in seq_len (why long_500k works)."""
    di, H, P, N, conv_dim = _dims(cfg)
    L, W = cfg.n_layers, cfg.conv_width
    return {
        "ssm": jnp.zeros((L, B, H, P, N), jnp.float32),
        "conv": jnp.zeros((L, B, W - 1, conv_dim), cfg.jnp_dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    extra_embeds=None,
    extra_slots: int = 0,  # accepted for API uniformity; state is O(1)
):
    """Prompt pass returning last logits + recurrent state cache."""
    di, H, P, N, conv_dim = _dims(cfg)
    B_, S = tokens.shape
    x = params["embed"][tokens]

    def block(x, lp):
        u = x
        h = rmsnorm(u, lp["ln"])
        z, xBC, dt_raw = _split_proj(cfg, h @ lp["w_in"])
        conv_tail = xBC[:, S - (cfg.conv_width - 1) :, :]
        xBC = jax.nn.silu(_causal_conv(xBC, lp["conv_w"], lp["conv_b"]))
        xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
        dt = jax.nn.softplus(
            dt_raw.astype(jnp.float32) + lp["dt_bias"][None, None]
        )
        A = -jnp.exp(lp["A_log"])
        y, h_fin = ssd_chunked(
            xs.reshape(B_, S, H, P), dt, A, Bm, Cm, cfg.ssm_chunk
        )
        y = y + lp["D_skip"][None, None, :, None] * xs.reshape(B_, S, H, P).astype(jnp.float32)
        y = y.reshape(B_, S, di).astype(u.dtype)
        y = rmsnorm(y, lp["norm"]) * jax.nn.silu(z)
        return u + y @ lp["w_out"], (h_fin, conv_tail)

    x, (ssm, conv) = jax.lax.scan(block, x, params["layers"])
    x = rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x[:, -1:] @ head
    return logits, {"ssm": ssm, "conv": conv, "len": jnp.asarray(S, jnp.int32)}


def decode_step(cfg: ModelConfig, params: dict, cache: dict, token: jax.Array):
    di, H, P, N, conv_dim = _dims(cfg)
    B_ = token.shape[0]
    x = params["embed"][token]  # (B, 1, D)

    def block(x, layer):
        lp, h_ssm, conv_tail = layer
        u = x
        h = rmsnorm(u, lp["ln"])
        z, xBC, dt_raw = _split_proj(cfg, h @ lp["w_in"])  # (B,1,*)
        # conv over [tail | current]
        window = jnp.concatenate([conv_tail, xBC], axis=1)  # (B, W, conv)
        conv_out = jnp.einsum(
            "bwc,wc->bc", window.astype(jnp.float32), lp["conv_w"].astype(jnp.float32)
        ) + lp["conv_b"].astype(jnp.float32)
        xBC = jax.nn.silu(conv_out)[:, None].astype(u.dtype)
        xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
        dt = jax.nn.softplus(
            dt_raw.astype(jnp.float32) + lp["dt_bias"][None, None]
        )
        A = -jnp.exp(lp["A_log"])
        y, h_new = ssd_decode(
            xs.reshape(B_, 1, H, P), dt, A, Bm, Cm, h_ssm
        )
        y = y + lp["D_skip"][None, None, :, None] * xs.reshape(B_, 1, H, P).astype(jnp.float32)
        y = y.reshape(B_, 1, di).astype(u.dtype)
        y = rmsnorm(y, lp["norm"]) * jax.nn.silu(z)
        out = u + y @ lp["w_out"]
        return out, (h_new, window[:, 1:])

    x, (ssm, conv) = jax.lax.scan(
        block, x, (params["layers"], cache["ssm"], cache["conv"])
    )
    x = rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, {"ssm": ssm, "conv": conv, "len": cache["len"] + 1}
