"""Shared LM loss with optional per-row weights.

Per-row weights are how the distributed csI-ADMM runtime expresses MDS
encode/decode over ECN batch partitions: the gradient is linear in
per-example losses, so "ECN j encodes sum_t B[j,t] g~_t, agent decodes
sum_j a_j g_j" folds into one weighted backward pass with row weight
a_j * B[j,t] (see repro.distributed.consensus).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["lm_loss"]


def lm_loss(
    logits: jax.Array,  # (B, S, V) — any float dtype; promoted to f32
    labels: jax.Array,  # (B, S) int, -100/-1 => ignore
    row_weights: Optional[jax.Array] = None,  # (B,)
) -> jax.Array:
    """Mean token NLL; with row_weights, sum_b w_b * (mean token NLL of row b).

    f_i in the paper is a mean over local examples; a "row" here is one
    example, its loss the mean NLL over its (unmasked) positions.
    """
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    lab = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    if row_weights is None:
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    row_loss = nll.sum(-1) / jnp.maximum(mask.sum(-1), 1)
    return jnp.sum(row_weights.astype(jnp.float32) * row_loss)
