"""Unified model configuration covering all assigned architecture families.

One dataclass drives dense / MoE / SSM / hybrid / VLM / audio backbones; the
per-architecture files in `repro.configs` instantiate it with the exact
assigned hyper-parameters (citations in each file).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0  # stablelm-2 partial rotary (0.25)
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    sliding_window: Optional[int] = None  # mixtral SWA / rg local attention
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    # mlp
    d_ff: int = 0
    mlp_act: str = "swiglu"  # swiglu | geglu | gelu
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # dispatch token-groups (set = data-axis size to keep the expert
    # scatter shard-local on a mesh; 1 = global dispatch)
    moe_groups: int = 1
    # mesh axis name to anchor the group dim to ("" = let XLA propagate)
    moe_shard_axis: str = ""
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    # hybrid (recurrentgemma): layer i is local-attention iff
    # (i % attn_every) == attn_every - 1, else RG-LRU recurrent.
    lru_width: int = 0
    attn_every: int = 0  # 3 => pattern [rec, rec, attn] (1:2)
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_positions: int = 0  # audio frames after the conv frontend (stub)
    # frontends (stubs per assignment carve-out)
    modality: str = "text"  # text | audio_stub | vision_stub
    # numerics
    dtype: str = "bfloat16"
    # training-time attention implementation: naive | blocked
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    tie_embeddings: bool = False
    # activation checkpointing of the layer scan (training path only):
    #   none | full (recompute everything from layer inputs) | dots
    #   (saveable = dots with no batch dims, XLA's matmul-output policy)
    remat: str = "none"
    # kernel backends: "jnp" (pure-XLA reference paths) or "pallas"
    # (repro.kernels; interpret-mode on CPU, native on TPU)
    attn_impl: str = "jnp"
    ssm_impl: str = "jnp"

    # ---- derived ---------------------------------------------------------

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_head(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k decode shape."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window is not None
        )

    @property
    def has_decoder(self) -> bool:
        """False only for encoder-only models (none assigned)."""
        return True

    def validate(self) -> None:
        if self.family in ("dense", "moe", "vlm", "audio"):
            assert self.n_heads > 0 and self.d_ff >= 0
            assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if self.family == "moe":
            assert 0 < self.experts_per_token <= self.n_experts
        if self.family == "ssm":
            assert self.ssm_state > 0 and self.ssm_heads > 0
        if self.family == "hybrid":
            assert self.attn_every > 1 and self.lru_width > 0
        if self.family == "audio":
            assert self.encoder_layers > 0

    def param_count(self) -> int:
        """Analytic parameter count (drives roofline MODEL_FLOPS = 6 N D)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        n = V * D  # embedding
        if not self.tie_embeddings:
            n += V * D
        if self.family == "ssm":
            di, ns, H = self.d_inner, self.ssm_state, self.ssm_heads
            conv_dim = di + 2 * ns  # x, B, C share the conv
            per = (
                D * (2 * di + 2 * ns + H)  # in_proj (z, x, B, C, dt)
                + conv_dim * self.conv_width
                + di * D  # out_proj
                + di  # gated norm scale
                + 2 * H  # A_log, dt_bias... (approx: D params)
                + D  # pre-norm
            )
            return n + L * per
        hd, nh, nkv = self.d_head, self.n_heads, self.n_kv_heads
        attn = D * nh * hd + 2 * D * nkv * hd + nh * hd * D
        if self.qk_norm:
            attn += 2 * hd
        if self.mlp_act in ("swiglu", "geglu"):
            mlp = 3 * D * F
        else:
            mlp = 2 * D * F
        norms = 2 * D
        if self.family == "moe":
            mlp = self.n_experts * 3 * D * F + D * self.n_experts
        if self.family == "hybrid":
            n_attn = L // self.attn_every
            n_rec = L - n_attn
            W = self.lru_width
            rec = 2 * D * W + W * self.conv_width + W * D + 4 * W
            return n + n_attn * (attn + mlp + norms) + n_rec * (rec + mlp + norms) + D
        if self.family == "audio":
            enc = self.encoder_layers * (attn + 2 * D * F + norms)
            dec = L * (attn + attn + 2 * D * F + 3 * D)  # self+cross attn
            return n + enc + dec + self.encoder_positions * D
        return n + L * (attn + mlp + norms) + D

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts)."""
        if self.family != "moe":
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        total = self.param_count()
        moe_all = L * self.n_experts * 3 * D * F
        moe_active = L * self.experts_per_token * 3 * D * F
        return total - moe_all + moe_active
