"""Model zoo: dense / MoE / SSM / hybrid / VLM / audio backbones in pure JAX."""

from .config import ModelConfig
from .registry import Model, get_model

__all__ = ["ModelConfig", "Model", "get_model"]
