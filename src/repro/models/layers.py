"""Shared neural layers (pure functions over param pytrees).

Everything is written against jnp + lax only — no flax/haiku — so the same
functions trace under jit/pjit on any mesh. Shapes use the conventions:

  B batch, S sequence, D d_model, H query heads, KV kv heads, hd head_dim,
  F d_ff, E experts, C expert capacity, W attention window.

Attention supports:
  - GQA (H != KV) via logical head grouping,
  - optional qk-norm (qwen3),
  - partial rotary (stablelm-2, fraction of head_dim rotated),
  - M-RoPE (qwen2-vl, 3-section rotary over (t, h, w) position ids),
  - causal and sliding-window masks,
  - a blocked (flash-style, online-softmax) path for long sequences that
    mirrors the Pallas kernel in `repro.kernels.flash_attention`,
  - single-token decode against a (ring-buffered) KV cache.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(
    x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5
) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# Rotary embeddings
# --------------------------------------------------------------------------


def rope_frequencies(
    rot_dim: int, theta: float, positions: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables. positions: (..., S) int -> (..., S, rot_dim/2)."""
    inv = 1.0 / (
        theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim)
    )
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(
    x: jax.Array,  # (B, S, H, hd)
    positions: jax.Array,  # (B, S) or (3, B, S) for M-RoPE
    theta: float,
    fraction: float = 1.0,
    mrope_sections: Optional[Tuple[int, int, int]] = None,
) -> jax.Array:
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]

    if mrope_sections is not None:
        # Qwen2-VL M-RoPE: the rot/2 frequency slots are split into three
        # sections driven by (temporal, height, width) position ids.
        sec = mrope_sections
        assert sum(sec) == rot // 2, (sec, rot)
        cos3, sin3 = rope_frequencies(rot, theta, positions)  # (3,B,S,rot/2)
        splits = [sec[0], sec[0] + sec[1]]  # static split points
        cos = jnp.concatenate(
            [c for c in (jnp.split(cos3[i], splits, axis=-1)[i] for i in range(3))],
            axis=-1,
        )
        sin = jnp.concatenate(
            [s for s in (jnp.split(sin3[i], splits, axis=-1)[i] for i in range(3))],
            axis=-1,
        )
    else:
        cos, sin = rope_frequencies(rot, theta, positions)  # (B,S,rot/2)

    cos = cos[..., None, :]  # (B, S, 1, rot/2)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
    return jnp.concatenate([y, x_pass], axis=-1) if rot < hd else y


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------


def _expand_kv(k: jax.Array, q_per_kv: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, KV*q_per_kv, hd) by repeat (GQA)."""
    if q_per_kv == 1:
        return k
    return jnp.repeat(k, q_per_kv, axis=2)


def naive_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, H, hd)  (already GQA-expanded)
    v: jax.Array,
    causal: bool,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    """Reference full-matrix attention (used for short sequences + oracles)."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def blocked_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, H, hd)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 512,
    block_kv: int = 1024,
) -> jax.Array:
    """Flash-style online-softmax attention in pure jnp (O(S*block) memory).

    Mirrors the Pallas kernel (repro.kernels.flash_attention); this is the
    lowering-friendly path used for long-sequence prefill/training. Blocks
    fully outside the causal/window band are still *computed* here (masked) —
    the Pallas kernel skips them; XLA's scan keeps memory bounded either way.
    """
    B, S, H, hd = q.shape
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)
    nq, nk = S // block_q, S // block_kv
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    qb = q.reshape(B, nq, block_q, H, hd).transpose(1, 0, 3, 2, 4)
    kb = k.reshape(B, nk, block_kv, H, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, block_kv, H, hd).transpose(1, 0, 3, 2, 4)

    def per_qblock(qi, qblk):  # qblk (B, H, bq, hd)
        q32 = qblk.astype(jnp.float32) * scale
        qpos = qi * block_q + jnp.arange(block_q)

        def kv_step(carry, inp):
            acc, m, l = carry
            ki, kblk, vblk = inp
            kpos = ki * block_kv + jnp.arange(block_kv)
            s = jnp.einsum(
                "bhqd,bhkd->bhqk", q32, kblk.astype(jnp.float32)
            )
            mask = jnp.ones((block_q, block_kv), dtype=bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32)
            )
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, H, block_q, hd), jnp.float32)
        m0 = jnp.full((B, H, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), (jnp.arange(nk), kb, vb)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out  # (B, H, bq, hd)

    out = jax.lax.map(
        lambda args: per_qblock(*args), (jnp.arange(nq), qb)
    )  # (nq, B, H, bq, hd)
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, C, KV, hd) — C = cache length (maybe ring)
    v_cache: jax.Array,
    valid: jax.Array,  # (B, C) bool — which cache slots participate
) -> jax.Array:
    """Single-token decode attention over a (possibly ring-buffered) cache.

    The cache stays in its storage dtype: the dots accumulate in f32 via
    ``preferred_element_type`` instead of materializing an f32 copy of the
    whole cache (which would double decode HBM traffic — decode is the
    bandwidth-bound step; see EXPERIMENTS.md §Perf decode note)."""
    B, C, KV, hd = k_cache.shape
    H = q.shape[2]
    # Heads are ordered group-major: q head h belongs to kv head h // (H/KV)
    # (consistent with _expand_kv's jnp.repeat).
    qg = q[:, 0].reshape(B, KV, H // KV, hd)  # (B, KV, qpk, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qs = (qg.astype(jnp.float32) * scale).astype(k_cache.dtype)
    s = jnp.einsum(
        "bgqd,bcgd->bgqc", qs, k_cache, preferred_element_type=jnp.float32
    )
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bgqc,bcgd->bgqd",
        p.astype(v_cache.dtype),
        v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def mlp_apply(x: jax.Array, p: dict, act: str) -> jax.Array:
    """Gated or plain MLP. p: w_gate/w_up/w_down (gated) or w_in/w_out."""
    if act in ("swiglu", "geglu"):
        g = x @ p["w_gate"]
        u = x @ p["w_up"]
        h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)) * u
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_in"])
    return h @ p["w_out"]


# --------------------------------------------------------------------------
# Mixture of Experts (capacity-based dropless-ish dispatch)
# --------------------------------------------------------------------------


def moe_apply(
    x: jax.Array,  # (T, D) flattened tokens
    p: dict,  # router (D, E), w_gate/w_up (E, D, F), w_down (E, F, D)
    n_experts: int,
    top_k: int,
    capacity_factor: float,
    act: str = "swiglu",
    groups: int = 1,
    shard_axis: str = "",
) -> Tuple[jax.Array, jax.Array]:
    """Top-k token-choice routing with per-expert capacity.

    Returns (out (T, D), aux_loss scalar). Sort-free dispatch: position of a
    token within its expert's buffer comes from a cumsum over the one-hot
    assignment; tokens past capacity are dropped (residual passes through).

    ``groups > 1`` dispatches per token-group with per-group capacity C/G
    (an explicit leading G dim on every intermediate). With ``shard_axis``
    set to the mesh data axis, every G-major intermediate — including the
    (G, E, C, D) dispatch buffers — is pinned to that axis and the expert
    weights are pinned replicated-over-data / TP-over-model, so the
    dispatch stays shard-local and the expert matmuls never contract over
    a data-sharded dimension (both pathologies cost TBs of all-reduce per
    step otherwise; EXPERIMENTS.md §Perf pair 1 iters 2-5). Capacity is
    enforced per group, a standard locality/quality trade.
    """
    T, D = x.shape
    E, k, G = n_experts, top_k, groups
    assert T % G == 0, (T, G)
    Tg = T // G
    C = int(max(1, capacity_factor * Tg * k / E))
    C = min(C, Tg)

    if shard_axis:
        from jax.sharding import PartitionSpec as _P

        def wsc(t, *spec):
            return jax.lax.with_sharding_constraint(t, _P(*spec))
    else:
        def wsc(t, *spec):
            return t

    # "pod+data" pins the group dim over multiple mesh axes (multi-pod)
    ax = tuple(shard_axis.split("+")) if shard_axis else None
    xg = wsc(x.reshape(G, Tg, D), ax, None, None)

    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (G, Tg, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch-style): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=(0, 1))  # mean router prob per expert
    assign = jax.nn.one_hot(gate_idx[..., 0], E)  # top-1 fraction
    fe = jnp.mean(assign, axis=(0, 1))
    aux = E * jnp.sum(fe * me)

    # Dispatch positions within each group: slot position of a token in its
    # expert's buffer = running count of prior slots for that expert.
    flat_e = gate_idx.reshape(G, Tg * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (G, Tg*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot  # exclusive cumsum per g
    pos = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=2)[..., 0]
    keep = pos < C  # (G, Tg*k)
    tok_idx = jnp.arange(Tg * k) // k
    e_safe = jnp.where(keep, flat_e, 0)
    p_safe = jnp.where(keep, pos, C - 1)
    vals = jnp.where(keep[..., None], xg[:, tok_idx], 0).astype(x.dtype)

    def scat(e_s, p_s, v):  # per group: (Tg*k,), (Tg*k,), (Tg*k, D)
        return jnp.zeros((E, C, D), x.dtype).at[e_s, p_s].add(v, mode="drop")

    buf = jax.vmap(scat)(e_safe, p_safe, vals)  # (G, E, C, D)
    buf = wsc(buf, ax, None, None, None)

    # Expert matmuls: weights replicated over data (FSDP gather happens on
    # the 100MB weight shards, not the multi-GB outputs), F TP over model.
    w_gate = wsc(p["w_gate"], None, None, "model" if ax else None)
    w_up = wsc(p["w_up"], None, None, "model" if ax else None)
    w_down = wsc(p["w_down"], None, "model" if ax else None, None)
    g = jnp.einsum("gecd,edf->gecf", buf, w_gate)
    u = jnp.einsum("gecd,edf->gecf", buf, w_up)
    h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)) * u
    h = wsc(h, ax, None, None, "model" if ax else None)
    y = jnp.einsum("gecf,efd->gecd", h, w_down)  # (G, E, C, D)
    y = wsc(y, ax, None, None, None)

    # Combine: gather each routed slot's output, weight by gate value.
    def gath(yb, e_s, p_s):  # per group
        return yb[e_s, p_s]  # (Tg*k, D)

    slot_out = jax.vmap(gath)(y, e_safe, p_safe)
    slot_out = jnp.where(keep[..., None], slot_out, 0)
    w = gate_vals.reshape(G, Tg * k, 1).astype(slot_out.dtype)

    def comb(so):  # per group: (Tg*k, D) -> (Tg, D)
        return jnp.zeros((Tg, D), so.dtype).at[tok_idx].add(so)

    out = jax.vmap(comb)(slot_out * w)  # (G, Tg, D)
    out = wsc(out, ax, None, None)
    return out.reshape(T, D).astype(x.dtype), aux.astype(jnp.float32)


def _z(like: jax.Array) -> jax.Array:
    """Zero index scalar matching ``like``'s dtype (x64-safe dus indices)."""
    return jnp.zeros((), like.dtype)


def maybe_remat(fn, remat: str):
    """Wrap a scan body in jax.checkpoint per the config policy.

    "full" saves only layer boundaries (max recompute, min memory);
    "dots" keeps matmul outputs (recomputes cheap elementwise/softmax only).
    """
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(f"unknown remat policy {remat!r}")
