PY := PYTHONPATH=src python

# Sweeps timed by the benchmark-in-CI gate (BENCH_ci.json vs
# benchmarks/baseline.json); keep in sync with benchmarks/baseline.json.
BENCH_SWEEPS := fig5,mesh_scale,fig3e_runtime,hetero_grid,code_frontier,adaptive_frontier,fleet_frontier,staleness_frontier,churn_grid
BENCH_JSON := BENCH_ci.json

# Coverage floor the CI matrix enforces on the coding + kernel +
# analysis + control layers (the certification machinery of DESIGN.md
# §11, the trace contracts of §14 and the online controller of §15):
# combined statement coverage of repro.core.coding, repro.kernels,
# repro.analysis and repro.control.
COV_TARGETS := --cov=repro.core.coding --cov=repro.kernels \
	--cov=repro.analysis --cov=repro.control
COV_FLOOR := 85

.PHONY: test test-cov test-slow bench bench-smoke bench-json \
	bench-baseline lint docs-check trace-lint trace-audit-baseline

# Tier-1 verification: the whole suite, stop on first failure.
test:
	$(PY) -m pytest -x -q

# Tier-1 suite under pytest-cov with the coding/kernels coverage floor —
# what the CI matrix runs (requires pytest-cov from requirements-dev.txt).
test-cov:
	$(PY) -m pytest -x -q $(COV_TARGETS) --cov-report=term \
		--cov-report=xml:coverage.xml --cov-fail-under=$(COV_FLOOR)

# Include the slow consensus x all-archs lowering tests.
test-slow:
	$(PY) -m pytest -q -m "slow or not slow"

# Full figure benchmarks (about a minute per figure on one CPU core).
bench:
	$(PY) -m benchmarks.run

# Fast signal: fig5 grid at smoke scale through the sweep engine,
# plus the kernel micro-benchmarks.
bench-smoke:
	$(PY) -m benchmarks.run --sweep fig5 --iters 120 --runs 2
	$(PY) -m benchmarks.run --only kernels

# Benchmark-in-CI pipeline (DESIGN.md §9): time the gated sweeps, write
# the machine-readable summary, fail on >1.5x wall-clock regression or
# any dispatch-count growth vs the committed baseline. CI and the local
# workflow invoke exactly this target.
bench-json:
	$(PY) -m benchmarks.run --sweep $(BENCH_SWEEPS) --iters 120 --runs 2 \
		--json $(BENCH_JSON)
	$(PY) -m benchmarks.check $(BENCH_JSON)

# Refresh the committed baseline after a deliberate perf change.
bench-baseline:
	$(PY) -m benchmarks.run --sweep $(BENCH_SWEEPS) --iters 120 --runs 2 \
		--json $(BENCH_JSON)
	$(PY) -m benchmarks.check $(BENCH_JSON) --update

# Ruff lint (config in pyproject.toml) — same command CI runs.
lint:
	ruff check src benchmarks tests tools

# Every DESIGN.md / EXPERIMENTS.md section cited from src/ and
# benchmarks/ must exist (tools/docs_check.py).
docs-check:
	$(PY) tools/docs_check.py

# Trace-contract gate (DESIGN.md §14): AST invariant lint over src/ plus
# the jaxpr audit of every registered kernel vs the pinned structural
# counts in benchmarks/trace_audit.json. CI runs exactly this target.
trace-lint:
	$(PY) tools/trace_lint.py

# Refresh the pinned jaxpr-audit counts after a deliberate trace change
# (same workflow as bench-baseline for the perf gate).
trace-audit-baseline:
	$(PY) tools/trace_lint.py --update-audit
