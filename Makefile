PY := PYTHONPATH=src python

.PHONY: test test-slow bench bench-smoke docs-check

# Tier-1 verification: the whole suite, stop on first failure.
test:
	$(PY) -m pytest -x -q

# Include the slow consensus x all-archs lowering tests.
test-slow:
	$(PY) -m pytest -q -m "slow or not slow"

# Full figure benchmarks (about a minute per figure on one CPU core).
bench:
	$(PY) -m benchmarks.run

# Fast signal: fig5 grid at smoke scale through the sweep engine,
# plus the kernel micro-benchmarks.
bench-smoke:
	$(PY) -m benchmarks.run --sweep fig5 --iters 120 --runs 2
	$(PY) -m benchmarks.run --only kernels

# Every DESIGN.md / EXPERIMENTS.md section cited from src/ and
# benchmarks/ must exist (tools/docs_check.py).
docs-check:
	$(PY) tools/docs_check.py
