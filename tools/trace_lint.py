#!/usr/bin/env python
"""Trace-contract gate: AST invariant lint + jaxpr trace audit.

Two layers (DESIGN.md §14), one exit code:

1. **AST lint** (`repro.analysis.astcheck`) — stdlib-only scan of
   ``src/`` for host/device-split violations, traced Python control
   flow, callbacks in scan bodies, unfrozen spec dataclasses,
   statics-key completeness, and deprecated-shim imports. Fast; runs
   first so a source-level violation fails before any jax import.
2. **Jaxpr audit** (`repro.analysis.traceaudit`) — lowers every
   registered kernel over a representative static-signature grid and
   gates the structural counts (pallas_call presence, zero callbacks,
   f64→f32 demotions, trace groups) against the committed
   ``benchmarks/trace_audit.json``.

Usage:
  python tools/trace_lint.py                 # both layers, gate vs pin
  python tools/trace_lint.py --ast-only      # source lint only (fast)
  python tools/trace_lint.py --audit-only    # jaxpr audit only
  python tools/trace_lint.py --update-audit  # refresh the pinned counts
  python tools/trace_lint.py PATH [PATH...]  # lint specific paths
                                             # (fixture corpus tests)

Run via ``make trace-lint``; CI runs it as the ``analysis`` job.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))


def run_ast_lint(paths: "list[pathlib.Path]") -> int:
    from repro.analysis.astcheck import lint_paths

    findings = lint_paths(paths, root=ROOT)
    for f in findings:
        print(f"  {f}")
    scanned = ", ".join(str(p) for p in paths)
    if findings:
        print(f"trace-lint[ast]: {len(findings)} finding(s) in {scanned}")
        return 1
    print(f"trace-lint[ast]: clean ({scanned})")
    return 0


def run_jaxpr_audit(update: bool) -> int:
    from repro.analysis import traceaudit

    report = traceaudit.audit_report()
    if update:
        traceaudit.write_baseline(report)
        print(
            f"trace-lint[jaxpr]: pinned {len(report)} grids to "
            f"{traceaudit.DEFAULT_BASELINE.relative_to(ROOT)}"
        )
        # --update still gates the unconditional contracts: a baseline
        # refresh must never pin a callback or a lost Pallas path.
        failures, _ = traceaudit.compare_report(report, None)
    else:
        baseline = traceaudit.load_baseline()
        if baseline is None:
            print(
                "trace-lint[jaxpr]: WARNING no benchmarks/trace_audit.json"
                " — run with --update-audit to pin"
            )
        failures, notes = traceaudit.compare_report(report, baseline)
        for n in notes:
            print(f"  note: {n}")
    for f in failures:
        print(f"  FAIL: {f}")
    if failures:
        print(f"trace-lint[jaxpr]: {len(failures)} contract failure(s)")
        return 1
    n_sigs = sum(len(e["signatures"]) for e in report.values())
    print(
        f"trace-lint[jaxpr]: {len(report)} grids / {n_sigs} static "
        "groups lowered clean"
    )
    return 0


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "paths", nargs="*", type=pathlib.Path,
        help="files/dirs to AST-lint (default: src/)",
    )
    ap.add_argument("--ast-only", action="store_true",
                    help="skip the jaxpr audit")
    ap.add_argument("--audit-only", action="store_true",
                    help="skip the AST lint")
    ap.add_argument("--update-audit", action="store_true",
                    help="rewrite benchmarks/trace_audit.json")
    args = ap.parse_args(argv)
    if args.ast_only and args.audit_only:
        ap.error("--ast-only contradicts --audit-only")

    rc = 0
    if not args.audit_only:
        paths = args.paths or [ROOT / "src"]
        rc |= run_ast_lint([pathlib.Path(p) for p in paths])
    if not args.ast_only and not args.paths:
        rc |= run_jaxpr_audit(update=args.update_audit)
    return rc


if __name__ == "__main__":
    sys.exit(main())
