#!/usr/bin/env python
"""Verify documentation stays in lockstep with the code. Two checks:

1. **Citations** — code and benchmarks cite documentation sections as
   ``DESIGN.md §N`` or ``EXPERIMENTS.md §Name`` (plus the quoted
   ``EXPERIMENTS.md 'Paper claims'`` form). Every such reference in
   ``src/`` and ``benchmarks/`` must resolve to a real heading.
2. **Sweep coverage** — every sweep registered in
   ``src/repro/experiments/registry.py`` (the keys of its ``SWEEPS``
   dict, recovered by ``ast.parse`` of the source so this script never
   imports jax) must be mentioned somewhere in EXPERIMENTS.md.
   Registering a sweep without documenting it fails CI, and a registry
   that parses to zero sweeps is itself an error — a silently empty
   check is worse than a failing one.

Run via ``make docs-check``.
"""

from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "benchmarks")
DOCS = ("DESIGN.md", "EXPERIMENTS.md")
REGISTRY = pathlib.Path("src/repro/experiments/registry.py")

# DESIGN.md §3  /  EXPERIMENTS.md §Perf  /  EXPERIMENTS.md 'Paper claims'
REF_RE = re.compile(
    r"(DESIGN\.md|EXPERIMENTS\.md)\s+(?:§(\w+)|'([^']+)'|\"([^\"]+)\")"
)


def doc_sections(doc_path: pathlib.Path) -> set:
    """Section anchors: '§N'-style tokens and quoted names from headings."""
    sections = set()
    for line in doc_path.read_text().splitlines():
        if not line.startswith("#"):
            continue
        heading = line.lstrip("#").strip()
        # "## §7 Batched experiment engine" -> anchor "7"
        m = re.match(r"§(\w+)\b", heading)
        if m:
            sections.add(m.group(1))
        # "## Perf" / "## Paper claims" -> anchors "Perf", "Paper claims"
        sections.add(heading)
        first = heading.split()[0] if heading.split() else ""
        sections.add(first)
    return sections


def citation_errors(root: pathlib.Path = ROOT) -> "tuple[list, int]":
    """(errors, n_refs) for every doc citation under SCAN_DIRS."""
    docs = {}
    missing_docs = []
    for name in DOCS:
        path = root / name
        if path.exists():
            docs[name] = doc_sections(path)
        else:
            missing_docs.append(name)

    errors = []
    n_refs = 0
    for d in SCAN_DIRS:
        for path in sorted((root / d).rglob("*.py")):
            text = path.read_text()
            for m in REF_RE.finditer(text):
                doc, para, squote, dquote = m.groups()
                target = para or squote or dquote
                n_refs += 1
                rel = path.relative_to(root)
                if doc in missing_docs:
                    errors.append(f"{rel}: cites {doc} which does not exist")
                    continue
                anchors = docs[doc]
                if target in anchors or any(
                    a.startswith(target) for a in anchors
                ):
                    continue
                errors.append(
                    f"{rel}: cites {doc} §{target!r} — no such section"
                )
    return errors, n_refs


def registered_sweeps(registry_text: str) -> "list[str]":
    """SWEEPS dict keys, recovered from the registry AST (no imports).

    The line-regex predecessor matched only the exact shape
    ``"name": factory,`` at end-of-line, so a trailing comment or a
    wrapped entry silently dropped that sweep from coverage checking.
    Parsing the module with ``ast`` makes the extraction insensitive to
    formatting; anything assigned to ``SWEEPS`` as a dict literal (plain
    or annotated assignment, at any nesting) contributes its string
    keys.
    """
    names: "list[str]" = []
    for node in ast.walk(ast.parse(registry_text)):
        value = None
        if isinstance(node, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == "SWEEPS"
            for t in node.targets
        ):
            value = node.value
        elif (
            isinstance(node, ast.AnnAssign)
            and isinstance(node.target, ast.Name)
            and node.target.id == "SWEEPS"
        ):
            value = node.value
        if isinstance(value, ast.Dict):
            for key in value.keys:
                if isinstance(key, ast.Constant) and isinstance(
                    key.value, str
                ):
                    names.append(key.value)
    return names


def sweep_coverage_errors(root: pathlib.Path = ROOT) -> "tuple[list, int]":
    """(errors, n_sweeps): registered sweeps EXPERIMENTS.md never mentions."""
    names = registered_sweeps((root / REGISTRY).read_text())
    if not names:
        return [f"{REGISTRY}: found no SWEEPS entries to check"], 0
    doc = (root / "EXPERIMENTS.md").read_text()
    errors = [
        f"{REGISTRY}: sweep '{name}' is registered but EXPERIMENTS.md "
        "never mentions it"
        for name in names
        if not re.search(rf"\b{re.escape(name)}\b", doc)
    ]
    return errors, len(names)


def main() -> int:
    cite_errors, n_refs = citation_errors()
    sweep_errors, n_sweeps = sweep_coverage_errors()
    errors = cite_errors + sweep_errors
    if errors:
        print(f"docs-check: {len(errors)} problem(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(
        f"docs-check: {n_refs} citations in {SCAN_DIRS} all resolve; "
        f"{n_sweeps} registered sweeps all documented in EXPERIMENTS.md"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
