#!/usr/bin/env python
"""Verify every DESIGN.md / EXPERIMENTS.md citation in the code resolves.

Code and benchmarks cite documentation sections as ``DESIGN.md §N`` or
``EXPERIMENTS.md §Name`` (plus the quoted ``EXPERIMENTS.md 'Paper
claims'`` form). This script greps ``src/`` and ``benchmarks/`` for such
references and fails if the cited section heading does not exist in the
doc. Run via ``make docs-check``.
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "benchmarks")
DOCS = ("DESIGN.md", "EXPERIMENTS.md")

# DESIGN.md §3  /  EXPERIMENTS.md §Perf  /  EXPERIMENTS.md 'Paper claims'
REF_RE = re.compile(
    r"(DESIGN\.md|EXPERIMENTS\.md)\s+(?:§(\w+)|'([^']+)'|\"([^\"]+)\")"
)


def doc_sections(doc_path: pathlib.Path) -> set:
    """Section anchors: '§N'-style tokens and quoted names from headings."""
    sections = set()
    for line in doc_path.read_text().splitlines():
        if not line.startswith("#"):
            continue
        heading = line.lstrip("#").strip()
        # "## §7 Batched experiment engine" -> anchor "7"
        m = re.match(r"§(\w+)\b", heading)
        if m:
            sections.add(m.group(1))
        # "## Perf" / "## Paper claims" -> anchors "Perf", "Paper claims"
        sections.add(heading)
        first = heading.split()[0] if heading.split() else ""
        sections.add(first)
    return sections


def main() -> int:
    docs = {}
    missing_docs = []
    for name in DOCS:
        path = ROOT / name
        if path.exists():
            docs[name] = doc_sections(path)
        else:
            missing_docs.append(name)

    errors = []
    n_refs = 0
    for d in SCAN_DIRS:
        for path in sorted((ROOT / d).rglob("*.py")):
            text = path.read_text()
            for m in REF_RE.finditer(text):
                doc, para, squote, dquote = m.groups()
                target = para or squote or dquote
                n_refs += 1
                rel = path.relative_to(ROOT)
                if doc in missing_docs:
                    errors.append(f"{rel}: cites {doc} which does not exist")
                    continue
                anchors = docs[doc]
                if target in anchors or any(
                    a.startswith(target) for a in anchors
                ):
                    continue
                errors.append(
                    f"{rel}: cites {doc} §{target!r} — no such section"
                )

    if errors:
        print(f"docs-check: {len(errors)} broken citation(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"docs-check: {n_refs} citations in {SCAN_DIRS} all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
