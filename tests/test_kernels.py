"""Pallas kernel validation: sweep shapes/dtypes vs. the pure-jnp oracles.

All kernels execute in interpret mode on CPU (the container has no TPU);
interpret mode runs the same kernel body Python, so BlockSpec indexing,
scratch carry and masking logic are what is being validated.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    coded_admm_update,
    coded_combine,
    flash_attention,
    rglru_scan,
    ssd_scan,
)
from repro.kernels.ref import (
    coded_admm_update_ref,
    coded_combine_ref,
    flash_attention_ref,
    rglru_scan_ref,
    ssd_scan_ref,
)

TOL = {
    jnp.float32: dict(rtol=1e-5, atol=1e-5),
    jnp.bfloat16: dict(rtol=2e-2, atol=2e-2),
}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# --------------------------------------------------------------------------
# coded_combine / coded_admm_update
# --------------------------------------------------------------------------


@pytest.mark.parametrize("J,n", [(3, 4096), (5, 5000), (16, 12_288), (2, 17)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_coded_combine(J, n, dtype):
    k1, k2 = jax.random.split(jax.random.key(J * n))
    msgs = _rand(k1, (J, n), dtype)
    coeffs = _rand(k2, (J,), jnp.float32)
    out = coded_combine(msgs, coeffs)
    ref = coded_combine_ref(msgs, coeffs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL[dtype])


@pytest.mark.parametrize("J,n", [(3, 4096), (4, 9999)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_coded_admm_update(J, n, dtype):
    keys = jax.random.split(jax.random.key(J + n), 5)
    msgs = _rand(keys[0], (J, n), dtype)
    coeffs = _rand(keys[1], (J,), jnp.float32)
    x = _rand(keys[2], (n,), dtype)
    y = _rand(keys[3], (n,), dtype)
    z = _rand(keys[4], (n,), dtype)
    tau = jnp.asarray(2.5, jnp.float32)
    rho = 1.0
    out = coded_admm_update(msgs, coeffs, x, y, z, tau, rho)
    ref = coded_admm_update_ref(msgs, coeffs, x, y, z, tau, rho)
    assert out.dtype == x.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL[dtype]
    )


def test_coded_combine_mask_guards_dead_rows():
    """Dead message rows are where-zeroed BEFORE the reduction: NaN/Inf
    garbage in never-arrived rows must not pollute the decode (a plain
    0 * NaN multiply would)."""
    J, n = 4, 1000
    rng = np.random.default_rng(0)
    msgs = rng.standard_normal((J, n)).astype(np.float32)
    msgs[2] = np.nan  # ECN 2 never responded; its buffer is garbage
    msgs[3] = np.inf
    coeffs = rng.standard_normal(J).astype(np.float32)
    mask = jnp.asarray([1.0, 1.0, 0.0, 0.0], jnp.float32)
    out = coded_combine(jnp.asarray(msgs), jnp.asarray(coeffs), mask)
    ref = coded_combine_ref(jnp.asarray(msgs), jnp.asarray(coeffs), mask)
    expect = coeffs[0] * msgs[0] + coeffs[1] * msgs[1]
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_coded_admm_update_mask_parity(dtype):
    """Kernel == oracle for masked decode patterns (deadline truncation)."""
    J, n = 6, 5000
    keys = jax.random.split(jax.random.key(17), 5)
    msgs = _rand(keys[0], (J, n), dtype)
    coeffs = _rand(keys[1], (J,), jnp.float32)
    x = _rand(keys[2], (n,), dtype)
    y = _rand(keys[3], (n,), dtype)
    z = _rand(keys[4], (n,), dtype)
    tau = jnp.asarray(1.3, jnp.float32)
    mask = jnp.asarray([1, 0, 1, 1, 0, 1], jnp.float32)
    out = coded_admm_update(msgs, coeffs, x, y, z, tau, 0.9, mask)
    ref = coded_admm_update_ref(msgs, coeffs, x, y, z, tau, 0.9, mask)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("family,K,S", [("mds", 6, 2), ("approx", 6, 2)])
def test_coded_kernels_real_family_patterns(family, K, S):
    """The new families' actual decode vectors — including a
    deadline-truncated sub-R pattern for the partial-recovery family —
    drive the fused kernel to the same update as the dense oracle and
    the analytic eq. (5a)."""
    from repro.core.coding import make_code

    code = make_code(family, K, S, seed=0)
    n = 700
    rng = np.random.default_rng(5)
    gbar = rng.standard_normal((K, n)).astype(np.float32)
    msgs = (code.B.astype(np.float32) @ gbar).astype(np.float32)
    patterns = [np.arange(K) >= S]  # an exact-at-R alive set
    if code.min_responses < code.R:
        trunc = np.zeros(K, dtype=bool)  # deadline caught r_min + 1 rows
        trunc[: code.min_responses + 1] = True
        patterns.append(trunc)
    for alive in patterns:
        a = code.decode_vector(alive).astype(np.float32)
        x = rng.standard_normal(n).astype(np.float32)
        y = rng.standard_normal(n).astype(np.float32)
        z = rng.standard_normal(n).astype(np.float32)
        tau, rho = 1.7, 0.8
        G = (a @ msgs) / K
        expect = (tau * x + rho * z + y - G) / (rho + tau)
        args = (
            jnp.asarray(msgs), jnp.asarray(a / K), jnp.asarray(x),
            jnp.asarray(y), jnp.asarray(z), jnp.asarray(tau), rho,
            jnp.asarray(alive, jnp.float32),
        )
        out = coded_admm_update(*args)
        ref = coded_admm_update_ref(*args)
        np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6)


def test_coded_kernels_f64_interpret_parity():
    """Under x64 the interpret-mode kernels accumulate in f64 end to end
    (the convergence suite's precision floor): parity vs the oracle at
    f64-tight tolerance."""
    from jax.experimental import enable_x64

    with enable_x64():
        J, n = 5, 3000
        rng = np.random.default_rng(7)
        msgs = jnp.asarray(rng.standard_normal((J, n)))
        coeffs = jnp.asarray(rng.standard_normal(J))
        x, y, z = (jnp.asarray(rng.standard_normal(n)) for _ in range(3))
        mask = jnp.asarray([1.0, 1.0, 0.0, 1.0, 1.0])
        tau = jnp.asarray(2.2)
        assert msgs.dtype == jnp.float64
        out_c = coded_combine(msgs, coeffs, mask)
        ref_c = coded_combine_ref(msgs, coeffs, mask)
        assert out_c.dtype == jnp.float64
        np.testing.assert_allclose(
            np.asarray(out_c), np.asarray(ref_c), rtol=1e-12, atol=1e-12
        )
        out_u = coded_admm_update(msgs, coeffs, x, y, z, tau, 0.7, mask)
        ref_u = coded_admm_update_ref(msgs, coeffs, x, y, z, tau, 0.7, mask)
        assert out_u.dtype == jnp.float64
        np.testing.assert_allclose(
            np.asarray(out_u), np.asarray(ref_u), rtol=1e-12, atol=1e-12
        )


def test_runtime_coeffs_and_mask_do_not_retrace():
    """Decode coefficients and deadline masks are DATA: feeding new
    values (new straggler patterns, new deadlines) must reuse the one
    compiled trace — the property that lets a whole code_frontier sweep
    share a single dispatch."""
    J, n = 4, 4096
    key = jax.random.key(3)
    msgs = _rand(key, (J, n), jnp.float32)
    x = y = z = _rand(key, (n,), jnp.float32)
    tau = jnp.asarray(1.0, jnp.float32)

    def call(c, m):
        return coded_admm_update(
            msgs, jnp.asarray(c, jnp.float32), x, y, z, tau, 1.0,
            jnp.asarray(m, jnp.float32),
        )

    call([1.0, 2.0, 3.0, 4.0], [1, 1, 1, 1])
    size0 = coded_admm_update._cache_size()
    call([0.5, 0.0, -1.0, 2.0], [1, 0, 1, 1])  # new pattern
    call([9.0, 9.0, 9.0, 9.0], [0, 0, 1, 0])  # deadline truncation
    assert coded_admm_update._cache_size() == size0


def test_coded_admm_update_matches_scan_admm_equation():
    """The fused kernel must equal the decode+x-update used in core.admm."""
    from repro.core.coding import paper_fig2_code

    code = paper_fig2_code()
    K, n = 3, 1000
    rng = np.random.default_rng(0)
    gbar = rng.standard_normal((K, n)).astype(np.float32)
    msgs = code.B.astype(np.float32) @ gbar
    alive = np.array([True, True, False])
    a = code.decode_vector(alive).astype(np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    z = rng.standard_normal(n).astype(np.float32)
    tau, rho = 1.7, 0.8
    G = (a @ msgs) / K  # eq. (6) with decode
    expect = (tau * x + rho * z + y - G) / (rho + tau)
    out = coded_admm_update(
        jnp.asarray(msgs), jnp.asarray(a / K), jnp.asarray(x), jnp.asarray(y),
        jnp.asarray(z), jnp.asarray(tau), rho,
    )
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "B,S,H,KV,hd,window",
    [
        (1, 256, 4, 4, 64, None),  # MHA causal
        (2, 256, 4, 2, 64, None),  # GQA
        (1, 512, 8, 1, 64, None),  # MQA
        (1, 512, 4, 2, 64, 128),  # sliding window
        (1, 384, 2, 2, 128, 100),  # non-pow2 window, hd=128
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, H, KV, hd, window, dtype):
    ks = jax.random.split(jax.random.key(S + H), 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=True, window=window)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=True,
        window=window,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL[dtype]
    )


def test_flash_attention_matches_model_layer():
    """Kernel == the models' blocked_attention (pre-expanded GQA) path."""
    from repro.models.layers import blocked_attention, _expand_kv

    B, S, H, KV, hd = 1, 512, 4, 2, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = _rand(ks[0], (B, S, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, KV, hd), jnp.float32)
    v = _rand(ks[2], (B, S, KV, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=200)
    ref = blocked_attention(
        q, _expand_kv(k, H // KV), _expand_kv(v, H // KV),
        causal=True, window=200, block_q=128, block_kv=128,
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5
    )


# --------------------------------------------------------------------------
# ssd_scan
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "B,S,H,P,N,chunk",
    [
        (1, 128, 2, 16, 32, 64),
        (2, 256, 4, 32, 64, 128),
        (1, 200, 2, 16, 32, 64),  # padded path (S not chunk multiple)
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.key(S * H), 4)
    x = _rand(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(_rand(ks[2], (H,), jnp.float32))
    Bm = _rand(ks[3], (B, S, N), dtype) / np.sqrt(N)
    Cm = _rand(ks[0], (B, S, N), dtype) / np.sqrt(N)
    y, h = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    y_ref, h_ref = ssd_scan_ref(x, dt, A, Bm, Cm)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), **tol)


def test_ssd_scan_matches_model_chunked():
    """Kernel == the mamba2 model's lax.scan ssd_chunked implementation."""
    from repro.models.mamba2 import ssd_chunked

    B, S, H, P, N = 1, 256, 2, 16, 32
    ks = jax.random.split(jax.random.key(1), 4)
    x = _rand(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(_rand(ks[2], (H,), jnp.float32))
    Bm = _rand(ks[3], (B, S, N), jnp.float32) / np.sqrt(N)
    Cm = _rand(ks[0], (B, S, N), jnp.float32) / np.sqrt(N)
    y_k, h_k = ssd_scan(x, dt, A, Bm, Cm, chunk=64)
    y_m, h_m = ssd_chunked(x, dt, A, Bm, Cm, chunk=64)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m), rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# rglru_scan
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "B,S,W,block_s,block_w",
    [
        (1, 256, 64, 128, 64),
        (2, 512, 128, 256, 64),  # channel tiling (W > block_w)
        (1, 96, 32, 256, 512),  # block_s > S fallback
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan(B, S, W, block_s, block_w, dtype):
    ks = jax.random.split(jax.random.key(S + W), 3)
    a = jax.nn.sigmoid(_rand(ks[0], (B, S, W), jnp.float32)).astype(dtype)
    b = _rand(ks[1], (B, S, W), dtype)
    h, hlast = rglru_scan(a, b, block_s=block_s, block_w=block_w)
    h_ref, hlast_ref = rglru_scan_ref(a, b)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), **tol)
    np.testing.assert_allclose(np.asarray(hlast), np.asarray(hlast_ref), **tol)


def test_rglru_scan_initial_state():
    B, S, W = 2, 128, 32
    ks = jax.random.split(jax.random.key(9), 3)
    a = jax.nn.sigmoid(_rand(ks[0], (B, S, W), jnp.float32))
    b = _rand(ks[1], (B, S, W), jnp.float32)
    h0 = _rand(ks[2], (B, W), jnp.float32)
    h, hlast = rglru_scan(a, b, h0, block_s=64)
    h_ref, hlast_ref = rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hlast), np.asarray(hlast_ref), rtol=1e-5, atol=1e-5)


def test_rglru_scan_matches_model():
    """Kernel == the rglru model's associative_scan path (given same gates)."""
    from repro.models.rglru import rglru_seq

    B, S, W = 1, 128, 32
    lp = {
        "lru_wa": jnp.eye(W) * 0.1,
        "lru_ba": jnp.full((W,), 1.0),
        "lru_wx": jnp.eye(W) * 0.1,
        "lru_bx": jnp.zeros((W,)),
        "lambda": jnp.full((W,), 1.0),
    }
    x = _rand(jax.random.key(3), (B, S, W), jnp.float32)
    h0 = jnp.zeros((B, W), jnp.float32)
    ys, hl = rglru_seq(lp, x, h0)
    # reproduce gates exactly as the model computes them
    from repro.models.rglru import _gates

    a, b = _gates(lp, x)
    h, hlast = rglru_scan(a, b, block_s=64)
    np.testing.assert_allclose(np.asarray(h), np.asarray(ys, np.float32), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hlast), np.asarray(hl), rtol=1e-5, atol=1e-5)


def test_flash_attention_q_offset_continuation():
    """q_offset positions a query block mid-sequence (chunked prefill):
    attending over a longer KV prefix must equal the tail of full attention."""
    B, S, H, hd = 1, 512, 2, 64
    ks = jax.random.split(jax.random.key(11), 3)
    q = _rand(ks[0], (B, S, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, H, hd), jnp.float32)
    v = _rand(ks[2], (B, S, H, hd), jnp.float32)
    full = flash_attention(q, k, v, causal=True)
    half = flash_attention(
        q[:, S // 2 :], k, v, causal=True, q_offset=S // 2
    )
    np.testing.assert_allclose(
        np.asarray(half), np.asarray(full[:, S // 2 :]), rtol=2e-5, atol=2e-5
    )
