"""Hypothesis property tests for the coded data-partition layout.

Kept separate from ``test_substrate.py`` so substrate tests run even when
``hypothesis`` is absent (optional dev dependency; see
``requirements-dev.txt``).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.coding import make_code
from repro.data import partition_for_code


@given(
    b=st.integers(6, 4096),
    K=st.integers(1, 6),
    S=st.integers(0, 2),
)
@settings(max_examples=60, deadline=None)
def test_partition_supports_cover_everything(b, K, S):
    """Property: every partition is stored by >= S+1 ECNs (repetition), so
    any S stragglers leave at least one live copy of every partition."""
    if S >= K or K % (S + 1) != 0 or b < K:
        return
    scheme = "fractional" if S else "uncoded"
    code = make_code(scheme, K, S)
    boundaries, supports = partition_for_code(b, code)
    assert boundaries[-1] == (b // K) * K
    counts = np.zeros(K, dtype=int)
    for sup in supports:
        counts[sup] += 1
    assert (counts >= S + 1).all()
