"""BAD: jnp array materialized on the host side of the split.

prepare() is pure numpy by contract — the driver stacks its outputs on
a leading runs axis and places them on devices itself; a jnp array here
commits host data to a device before layout is known (DESIGN.md §2).
"""

import jax.numpy as jnp
import numpy as np


class EagerKernel(MethodKernel):  # noqa: F821 — AST fixture, never imported
    name = "eager-fixture"

    def prepare(self, problem, net, cfg, iters):
        data = jnp.asarray(np.ones(4))  # <-- device-array-in-host-prepare
        return Prepared(  # noqa: F821
            consts=(data,), steps=(),
            statics=dict(name=self.name, iters=iters),
        )

    def step(self, state, inp, aux, statics):
        return state, state
