"""BAD: spec dataclass without frozen=True.

Spec dataclasses (`*Config`/`*Run`/`*Spec`, `Case`, `Reduction`, ...)
are jit cache keys and grid dedupe keys; a mutable one invites in-place
edits that silently split (or poison) the trace cache (DESIGN.md §7).
"""

import dataclasses


@dataclasses.dataclass  # <-- spec-dataclass-not-frozen
class WobblyConfig:
    rho: float = 1.0
    iters: int = 100
