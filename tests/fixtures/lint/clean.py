"""GOOD: a kernel that honors every trace contract.

numpy sampling in prepare, pure-jnp step, branching only on statics (a
Python-level dict), a frozen spec dataclass, and every statics key the
step reads produced by prepare. `tests/test_trace_analysis.py` asserts
zero findings here — the linter's false-positive guard.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TidyConfig:
    rho: float = 1.0
    damped: bool = False


class TidyKernel(MethodKernel):  # noqa: F821 — AST fixture, never imported
    name = "tidy-fixture"

    def prepare(self, problem, net, cfg, iters):
        rng = np.random.default_rng(0)
        steps = rng.normal(size=(iters, 3))
        return Prepared(  # noqa: F821
            consts=(steps.sum(0),),
            steps=(steps,),
            statics=dict(name=self.name, iters=iters,
                         damped=cfg.damped),
        )

    def step(self, state, inp, aux, statics):
        x = state + jnp.tanh(inp)
        if statics["damped"]:  # statics branch: legal, part of the key
            x = x * 0.5
        x = jnp.where(x > 1.0, 1.0, x)  # traced branch done the jnp way
        return x, x

    def final(self, state, aux, statics):
        return state, state
