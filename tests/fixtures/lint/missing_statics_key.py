"""BAD: step reads a statics key no host-side construction produces.

The statics dict doubles as the jit cache key (`_statics_key`); a key
consumed in step but absent from every prepare/_statics is a latent
KeyError and a signature-completeness hole (DESIGN.md §8).
"""


class ForgetfulKernel(MethodKernel):  # noqa: F821 — AST fixture, never imported
    name = "forgetful-fixture"

    def prepare(self, problem, net, cfg, iters):
        return Prepared(  # noqa: F821
            consts=(), steps=(), statics=dict(name=self.name, iters=iters)
        )

    def step(self, state, inp, aux, statics):
        gain = statics["ghost_gain"]  # <-- statics-key-not-in-signature
        return state * gain, state
