"""BAD: host RNG inside a device-side step body.

`np.random` in a traced function is at best a trace-time constant (the
"noise" freezes into the compiled scan) and at worst a crash; sampling
belongs in prepare() (DESIGN.md §2).
"""

import numpy as np


class RngKernel(MethodKernel):  # noqa: F821 — AST fixture, never imported
    name = "rng-fixture"

    def prepare(self, problem, net, cfg, iters):
        return Prepared(  # noqa: F821
            consts=(), steps=(), statics=dict(name=self.name, iters=iters)
        )

    def step(self, state, inp, aux, statics):
        noise = np.random.normal(size=3)  # <-- host-rng-in-device-code
        return state + noise, state
