"""BAD: Python `if` on a traced value inside the scan body.

The scan body is traced once per static signature; a Python branch on a
traced scalar is a ConcretizationTypeError under jit — and if it DID
evaluate, it would silently pin one branch into every iteration. Branch
on statics or use jnp.where / lax.cond (DESIGN.md §7).
"""


class BranchyKernel(MethodKernel):  # noqa: F821 — AST fixture, never imported
    name = "branchy-fixture"

    def prepare(self, problem, net, cfg, iters):
        return Prepared(  # noqa: F821
            consts=(), steps=(), statics=dict(name=self.name, iters=iters)
        )

    def step(self, state, inp, aux, statics):
        x, k = state
        if k > 0:  # <-- traced-python-control-flow
            x = x * 0.5
        return (x, k), x
