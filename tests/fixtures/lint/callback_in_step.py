"""BAD: debug callback inside the scan body.

jax.debug.print / pure_callback / io_callback round-trip through the
host every scan iteration, and pallas_call + callbacks have no SPMD
story — the sharded tier walls off (DESIGN.md §9).
"""

import jax


class ChattyKernel(MethodKernel):  # noqa: F821 — AST fixture, never imported
    name = "chatty-fixture"

    def prepare(self, problem, net, cfg, iters):
        return Prepared(  # noqa: F821
            consts=(), steps=(), statics=dict(name=self.name, iters=iters)
        )

    def step(self, state, inp, aux, statics):
        jax.debug.print("state {}", state)  # <-- callback-in-scan-body
        return state, state
