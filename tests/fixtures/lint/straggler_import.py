"""BAD: import of the deprecated repro.core.straggler shim.

The shim only exists for external callers mid-migration; in-repo code
imports TimingModel from repro.core.timing (DESIGN.md §13).
"""

from repro.core.straggler import StragglerModel  # <-- deprecated import

__all__ = ["StragglerModel"]
