"""Baseline methods (W-ADMM, D-ADMM, DGD, EXTRA) converge and their
communication accounting matches the paper's cost model (§IV-B, §V-A)."""

import pytest

from repro.core import (
    ADMMConfig,
    allocate,
    make_network,
    run_dadmm,
    run_dgd,
    run_extra,
    run_incremental_admm,
    run_wadmm,
)
from repro.core.problems import _planted


@pytest.fixture(scope="module")
def prob():
    ds = _planted(6000, 600, 5, 2, 0.05, seed=3, name="small")
    return allocate(ds, N=6, K=3)


@pytest.fixture(scope="module")
def net():
    return make_network(6, connectivity=0.6, seed=1)


def test_wadmm_converges(prob, net):
    cfg = ADMMConfig(rho=1.0, c_tau=0.5, c_gamma=2.0, M=60)
    tr = run_wadmm(prob, net, cfg, iters=3000)
    assert tr.z_err[-1] < 3e-2


def test_dadmm_converges(prob, net):
    tr = run_dadmm(prob, net, rho=0.5, iters=400)
    assert tr.accuracy[-1] < 1e-6


def test_dgd_converges(prob, net):
    tr = run_dgd(prob, net, alpha0=0.5, iters=3000)
    assert tr.accuracy[-1] < 1e-2


def test_extra_converges(prob, net):
    tr = run_extra(prob, net, alpha=0.3, iters=1500)
    assert tr.accuracy[-1] < 1e-6


def test_incremental_is_communication_cheaper(prob, net):
    """Paper's headline: incremental methods use 1 link/iter vs 2|E| for
    gossip — so at equal communication budget sI-ADMM reaches much lower
    error than DGD (Fig. 3c/d)."""
    budget = 500  # communication units (the regime of Fig. 3c: few units)
    cfg = ADMMConfig(rho=1.0, c_tau=0.5, c_gamma=2.0, M=60)
    tr_si = run_incremental_admm(prob, net, cfg, iters=budget)
    gossip_iters = max(budget // (2 * net.E), 1)
    tr_dgd = run_dgd(prob, net, alpha0=0.5, iters=gossip_iters)
    assert tr_si.comm_cost[-1] <= budget
    assert tr_dgd.comm_cost[-1] <= budget + 2 * net.E
    assert tr_si.accuracy[-1] < tr_dgd.accuracy[-1]


def test_comm_cost_accounting(prob, net):
    cfg = ADMMConfig(rho=1.0, c_tau=0.5, c_gamma=2.0, M=60)
    tr = run_incremental_admm(prob, net, cfg, iters=100)
    assert tr.comm_cost[-1] == 100  # one unit per token hop
    tr = run_dgd(prob, net, alpha0=0.5, iters=10)
    assert tr.comm_cost[-1] == 10 * 2 * net.E
