"""Network topology tests (paper §II Assumption 1, §V-A setup)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import make_network, metropolis_weights


@settings(max_examples=20, deadline=None)
@given(N=st.integers(3, 30), eta=st.floats(0.1, 1.0), seed=st.integers(0, 99))
def test_property_network_connected_with_hamiltonian(N, eta, seed):
    """Assumption 1: connected and at least one Hamiltonian cycle."""
    net = make_network(N, eta, seed=seed)
    assert net.N == N
    # Hamiltonian order visits each agent exactly once...
    assert sorted(net.hamiltonian) == list(range(N))
    # ...along existing edges.
    A = net.adjacency
    for a in range(N):
        i, j = net.hamiltonian[a], net.hamiltonian[(a + 1) % N]
        assert A[i, j]
    # Shortest-path cycle visits every agent, along edges.
    assert set(net.shortest_path_cycle) == set(range(N))
    r = net.shortest_path_cycle
    for a in range(len(r)):
        assert A[r[a], r[(a + 1) % len(r)]]


def test_connectivity_ratio():
    net = make_network(20, connectivity=0.5, seed=0)
    target = 0.5 * 20 * 19 / 2
    assert abs(net.E - target) <= 1


def test_metropolis_weights_doubly_stochastic():
    net = make_network(12, 0.4, seed=2)
    W = metropolis_weights(net)
    np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-12)
    np.testing.assert_allclose(W, W.T)
    # spectral: second eigenvalue < 1 (connected)
    ev = np.sort(np.abs(np.linalg.eigvalsh(W)))
    assert ev[-1] <= 1 + 1e-12


def test_small_network_rejected():
    with pytest.raises(ValueError):
        make_network(2)
