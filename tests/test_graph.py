"""Network topology tests (paper §II Assumption 1, §V-A setup).

The hypothesis property variant lives in ``test_graph_properties.py``
(optional dev dependency; see ``requirements-dev.txt``).
"""

import numpy as np
import pytest

from repro.core import make_network, metropolis_weights


def test_network_connected_with_hamiltonian():
    """Assumption 1: connected and at least one Hamiltonian cycle."""
    for N, eta, seed in [(3, 0.5, 0), (10, 0.3, 1), (30, 0.8, 2)]:
        net = make_network(N, eta, seed=seed)
        assert net.N == N
        # Hamiltonian order visits each agent exactly once...
        assert sorted(net.hamiltonian) == list(range(N))
        # ...along existing edges.
        A = net.adjacency
        for a in range(N):
            i, j = net.hamiltonian[a], net.hamiltonian[(a + 1) % N]
            assert A[i, j]
        # Shortest-path cycle visits every agent, along edges.
        assert set(net.shortest_path_cycle) == set(range(N))
        r = net.shortest_path_cycle
        for a in range(len(r)):
            assert A[r[a], r[(a + 1) % len(r)]]


def test_connectivity_ratio():
    net = make_network(20, connectivity=0.5, seed=0)
    target = 0.5 * 20 * 19 / 2
    assert abs(net.E - target) <= 1


def test_metropolis_weights_doubly_stochastic():
    net = make_network(12, 0.4, seed=2)
    W = metropolis_weights(net)
    np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-12)
    np.testing.assert_allclose(W, W.T)
    # spectral: second eigenvalue < 1 (connected)
    ev = np.sort(np.abs(np.linalg.eigvalsh(W)))
    assert ev[-1] <= 1 + 1e-12


def test_small_network_rejected():
    with pytest.raises(ValueError):
        make_network(2)
