"""MethodKernel protocol tests (DESIGN.md §8).

The contract: every registered method has ONE step implementation, and
the batched engine (`vmap` of that step) matches the serial driver
(`lax.scan` of that step) elementwise — for the paper's six algorithms
AND the two beyond-paper variants that ship through the protocol only
(pI-ADMM privacy noise, cq-sI-ADMM compressed tokens).
"""

import numpy as np
import pytest

from repro.core.admm import ADMMConfig, run_incremental_admm
from repro.core.graph import make_network
from repro.core.problems import DATASETS, allocate
from repro.experiments import Case, run_sweep
from repro.experiments.sweep import METHODS
from repro.methods import KERNELS, get_kernel, run_serial
from repro.methods.admm import ADMMRun
from repro.methods.compression import CompressionRun

ITERS = 40
ALL_METHODS = (
    "sI-ADMM", "csI-ADMM", "I-ADMM", "W-ADMM", "D-ADMM", "DGD", "EXTRA",
    "pI-ADMM", "cq-sI-ADMM", "a-csI-ADMM",
)


def _case(method: str, seed: int = 0, **kw) -> Case:
    incremental = method not in ("D-ADMM", "DGD", "EXTRA", "W-ADMM")
    kw.setdefault("M", 36 if incremental else 33)
    if method == "csI-ADMM":
        kw.setdefault("S", 1)
        kw.setdefault("scheme", "cyclic")
    if method == "a-csI-ADMM":
        kw.setdefault(
            "arms", (("cyclic", 1, None), ("approx", 1, 3e-4))
        )
    return Case(
        method=method, dataset="usps", N=5, K=3, iters=ITERS, seed=seed, **kw
    )


def test_registry_covers_every_method():
    assert set(METHODS) == set(KERNELS) == set(ALL_METHODS)
    with pytest.raises(KeyError, match="unknown method"):
        get_kernel("nope")


def test_batched_matches_serial_every_method():
    """vmap-of-step == scan-of-step elementwise, for all ten kernels."""
    cases = [_case(m, seed=s) for m in ALL_METHODS for s in (0, 1)]
    batched = run_sweep(cases)
    serial = run_sweep(cases, serial=True)
    # sI and csI share the ADMM family signature (S/scheme are runtime
    # inputs) and merge into one dispatch; every other method is its own.
    assert batched.n_dispatches == len(ALL_METHODS) - 1
    for case, tb, ts in zip(cases, batched.traces, serial.traces):
        for field in ("accuracy", "test_error", "z_err", "comm_cost",
                      "sim_time", "final_x", "final_z"):
            np.testing.assert_allclose(
                getattr(tb, field), getattr(ts, field),
                rtol=1e-5, atol=1e-5, err_msg=f"{case.method} field={field}",
            )
        assert np.isfinite(tb.accuracy).all(), case.method


def test_piadmm_sigma_zero_is_exactly_siadmm():
    """The noise-free control arm of the privacy kernel is sI-ADMM."""
    case = _case("pI-ADMM", sigma=0.0)
    net = make_network(case.N, case.connectivity, seed=case.seed)
    prob = allocate(DATASETS[case.dataset](case.seed), case.N, case.K)
    kernel = get_kernel("pI-ADMM")
    tr = run_serial(kernel, prob, net, kernel.config(case), ITERS)
    ref = run_incremental_admm(prob, net, case.admm_config(), ITERS)
    np.testing.assert_allclose(tr.accuracy, ref.accuracy, rtol=1e-12)
    np.testing.assert_allclose(
        tr.final_z, ref.final_z, rtol=1e-12, atol=1e-13
    )


def test_piadmm_noise_perturbs_iterates():
    case = _case("pI-ADMM", sigma=0.5)
    net = make_network(case.N, case.connectivity, seed=case.seed)
    prob = allocate(DATASETS[case.dataset](case.seed), case.N, case.K)
    kernel = get_kernel("pI-ADMM")
    tr = run_serial(kernel, prob, net, kernel.config(case), ITERS)
    ref = run_incremental_admm(prob, net, case.admm_config(), ITERS)
    assert np.abs(tr.final_z - ref.final_z).max() > 1e-6


def test_cq_topk_full_fraction_is_exactly_siadmm():
    """frac=1.0 keeps every token entry: the compressor is the identity
    and the error-feedback accumulator stays exactly zero."""
    case = _case("cq-sI-ADMM", compressor="topk", frac=1.0)
    net = make_network(case.N, case.connectivity, seed=case.seed)
    prob = allocate(DATASETS[case.dataset](case.seed), case.N, case.K)
    kernel = get_kernel("cq-sI-ADMM")
    tr = run_serial(kernel, prob, net, kernel.config(case), ITERS)
    ref = run_incremental_admm(prob, net, case.admm_config(), ITERS)
    np.testing.assert_allclose(tr.accuracy, ref.accuracy, rtol=1e-12)
    # atol: the two kernels compile into separately-fused executables of
    # the same step math; XLA's fusion choices around the Pallas x-update
    # may differ by reassociation, so equality is ULP-level, not bitwise.
    np.testing.assert_allclose(
        tr.final_z, ref.final_z, rtol=1e-12, atol=1e-13
    )


def test_cq_comm_accounting():
    """Compressed token hops are charged their true bit cost, side
    information included (quant: sign + per-token scale; topk: indices),
    relative to the 32-bit dense token's 1 unit."""
    net = make_network(5, 0.5, seed=0)
    prob = allocate(DATASETS["usps"](0), 5, 3)
    pd = prob.p * prob.d
    kernel = get_kernel("cq-sI-ADMM")
    run = CompressionRun(ADMMConfig(M=36, K=3), compressor="quant", bits=8)
    tr = run_serial(kernel, prob, net, run, ITERS)
    assert tr.comm_cost[-1] == pytest.approx(
        ITERS * ((8 + 1) * pd + 32) / (32 * pd)
    )
    run = CompressionRun(ADMMConfig(M=36, K=3), compressor="topk", frac=0.25)
    tr = run_serial(kernel, prob, net, run, ITERS)
    k = int(np.ceil(0.25 * pd))
    idx_bits = int(np.ceil(np.log2(pd)))
    assert tr.comm_cost[-1] == pytest.approx(
        ITERS * k * (32 + idx_bits) / (32 * pd)
    )
    # compression must actually pay off versus the dense token
    assert tr.comm_cost[-1] < ITERS


def test_cq_compressed_still_converges():
    """Error feedback keeps compressed tokens on the sI-ADMM path: both
    compressors end within a small factor of the uncompressed error."""
    net = make_network(5, 0.5, seed=0)
    prob = allocate(DATASETS["usps"](0), 5, 3)
    iters = 600
    ref = run_incremental_admm(
        prob, net, ADMMConfig(M=36, K=3, c_tau=0.5), iters
    )
    kernel = get_kernel("cq-sI-ADMM")
    for kw in (dict(compressor="topk", frac=0.25),
               dict(compressor="quant", bits=8)):
        run = CompressionRun(ADMMConfig(M=36, K=3, c_tau=0.5), **kw)
        tr = run_serial(kernel, prob, net, run, iters)
        assert tr.z_err[-1] < max(3.0 * ref.z_err[-1], 0.1), kw


def test_config_validation_errors():
    net = make_network(5, 0.5, seed=0)
    prob = allocate(DATASETS["usps"](0), 5, 3)
    kernel = get_kernel("cq-sI-ADMM")
    with pytest.raises(ValueError, match="frac"):
        run_serial(
            kernel, prob, net,
            CompressionRun(ADMMConfig(M=36, K=3), compressor="topk", frac=0.0),
            10,
        )
    with pytest.raises(ValueError, match="unknown compressor"):
        run_serial(
            kernel, prob, net,
            CompressionRun(ADMMConfig(M=36, K=3), compressor="nope"),
            10,
        )
    with pytest.raises(ValueError, match="code does not match"):
        from repro.core.coding import make_code

        run_serial(
            get_kernel("csI-ADMM"), prob, net,
            ADMMRun(
                ADMMConfig(M=36, K=3, S=1, scheme="cyclic"),
                code=make_code("cyclic", 3, 2),
            ),
            10,
        )
