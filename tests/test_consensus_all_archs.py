"""The paper's technique composes with every assigned architecture family:
two live csI-ADMM steps (coded batch, random straggler) on each reduced
config — MoE routing, SSM state, RG-LRU hybrid, VLM/audio stubs included."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.distributed import ConsensusConfig, ConsensusRuntime
from repro.models import get_model

A, K, S, P_ROWS, SEQ = 2, 4, 1, 1, 32


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_consensus_step_every_arch(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    ccfg = ConsensusConfig(
        n_agents=A, K=K, S=S, scheme="cyclic", mode="incremental",
        rho=1.0, c_tau=5.0, c_gamma=0.1,
    )
    mesh = jax.make_mesh((1, 1, 1), ("agent", "data", "model"))
    rt = ConsensusRuntime(model, ccfg, mesh)
    code = ccfg.code()
    sup = [code.support(j) for j in range(K)]

    rng = np.random.default_rng(0)
    # coded allocation of an LM batch: K distinct partitions per agent,
    # partition t replicated on the ECNs whose supports contain it
    distinct = rng.integers(
        0, cfg.vocab, size=(A, K, P_ROWS, SEQ + 1), dtype=np.int32
    )
    rows = []
    for a in range(A):
        for j in range(K):
            for t in sup[j]:
                rows.append(distinct[a, t])
    flat = np.concatenate(rows)  # (A*K*(S+1)*P, SEQ+1)
    batch = {
        "tokens": jnp.asarray(flat[:, :-1]),
        "labels": jnp.asarray(flat[:, 1:]),
    }
    B = flat.shape[0]
    if cfg.modality == "vision_stub":
        batch["extra_embeds"] = jnp.ones((B, 16, cfg.d_model), cfg.jnp_dtype) * 0.01
    elif cfg.modality == "audio_stub":
        batch["extra_embeds"] = (
            jnp.ones((B, cfg.encoder_positions, cfg.d_model), cfg.jnp_dtype) * 0.01
        )

    state = rt.init_state(jax.random.key(0))
    step = jax.jit(rt.train_step)
    for k in range(2):
        alive = np.ones((A, K), bool)
        for a in range(A):
            alive[a, rng.integers(K)] = False  # one straggler per agent
        state, metrics = step(state, batch, jnp.asarray(alive))
        assert np.isfinite(float(metrics["loss"])), (arch, k)
        assert np.isfinite(float(metrics["consensus_residual"])), (arch, k)
    assert int(state["k"]) == 2
    # z must have moved (the technique actually updates the model)
    z0 = jax.tree.leaves(rt.init_state(jax.random.key(0))["z"])
    z2 = jax.tree.leaves(state["z"])
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(z0, z2)
    )
    assert moved, arch
