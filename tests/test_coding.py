"""Unit + property tests for (K, R) MDS gradient coding (paper §III-B)."""

import itertools

import numpy as np
import pytest

from repro.core.coding import (
    GradientCode,
    cyclic_repetition_code,
    fractional_repetition_code,
    paper_fig2_code,
    uncoded,
)


def _exhaustive_straggler_check(code: GradientCode, rng):
    """Any S stragglers: decode == exact partition-gradient sum."""
    g = rng.standard_normal((code.K, 7))
    expected = g.sum(0)
    msgs = code.encode(g)
    for dead in itertools.combinations(range(code.K), code.S):
        alive = np.ones(code.K, dtype=bool)
        alive[list(dead)] = False
        np.testing.assert_allclose(
            code.decode(msgs, alive), expected, rtol=1e-9, atol=1e-9
        )


@pytest.mark.parametrize("K,S", [(3, 1), (4, 1), (4, 2), (6, 2), (9, 2), (10, 4)])
def test_cyclic_exact_recovery(K, S):
    _exhaustive_straggler_check(
        cyclic_repetition_code(K, S), np.random.default_rng(0)
    )


@pytest.mark.parametrize("K,S", [(4, 1), (6, 1), (6, 2), (9, 2), (8, 3)])
def test_fractional_exact_recovery(K, S):
    _exhaustive_straggler_check(
        fractional_repetition_code(K, S), np.random.default_rng(1)
    )


def test_fractional_requires_divisibility():
    with pytest.raises(ValueError):
        fractional_repetition_code(5, 1)  # (S+1)=2 does not divide 5


def test_paper_fig2_example():
    """The exact K=3, S=1 example of Fig. 2 and its decode vectors."""
    code = paper_fig2_code()
    g = np.random.default_rng(2).standard_normal((3, 4))
    msgs = code.encode(g)
    # g1 = 1/2 g~1 + g~2, g2 = g~2 - g~3, g3 = 1/2 g~1 + g~3
    np.testing.assert_allclose(msgs[0], 0.5 * g[0] + g[1])
    np.testing.assert_allclose(msgs[1], g[1] - g[2])
    np.testing.assert_allclose(msgs[2], 0.5 * g[0] + g[2])
    # "any of first two arrived messages can recover the summation"
    for dead in range(3):
        alive = np.ones(3, dtype=bool)
        alive[dead] = False
        np.testing.assert_allclose(code.decode(msgs, alive), g.sum(0))
    # Fig. 2 decode for alive={0,2}: g1 + g3 = sum
    a = code.decode_vector(np.array([True, False, True]))
    np.testing.assert_allclose(a, [1.0, 0.0, 1.0], atol=1e-9)


def test_cyclic_support_structure():
    code = cyclic_repetition_code(6, 2)
    for j in range(6):
        assert set(code.support(j)) == {(j + t) % 6 for t in range(3)}
    assert code.replication == 3  # S+1 partitions per ECN


def test_uncoded_is_identity():
    code = uncoded(4)
    np.testing.assert_allclose(code.B, np.eye(4))
    assert code.R == 4


def test_decode_rejects_too_few():
    code = cyclic_repetition_code(4, 1)
    with pytest.raises(ValueError):
        code.decode_vector(np.array([True, True, False, False]))
