"""Convergence and invariant tests for (c)sI-ADMM — paper Theorems 1-2, Cor. 1-2."""

import numpy as np
import pytest

from repro.core import (
    ADMMConfig,
    StragglerModel,
    allocate,
    make_network,
    run_incremental_admm,
)
from repro.core.problems import _planted


@pytest.fixture(scope="module")
def small_problem():
    ds = _planted(6000, 600, 5, 2, 0.05, seed=3, name="small")
    return allocate(ds, N=6, K=3)


@pytest.fixture(scope="module")
def net6():
    return make_network(6, connectivity=0.6, seed=1)


def test_iadmm_exact_converges(small_problem, net6):
    """I-ADMM (eq. 4, exact x-update) drives z to the global optimum."""
    cfg = ADMMConfig(rho=1.0, exact_x=True)
    tr = run_incremental_admm(small_problem, net6, cfg, iters=1800)
    assert tr.z_err[-1] < 1e-3
    assert tr.accuracy[-1] < 1e-2


def test_siadmm_converges(small_problem, net6):
    cfg = ADMMConfig(rho=1.0, c_tau=0.5, c_gamma=2.0, M=60, K=3, S=0)
    tr = run_incremental_admm(small_problem, net6, cfg, iters=3000)
    assert tr.z_err[-1] < 2e-2
    # monotone-ish: final accuracy well below the start
    assert tr.accuracy[-1] < 0.05 * tr.accuracy[0]


@pytest.mark.parametrize("scheme,K,S", [("cyclic", 3, 1), ("fractional", 4, 1)])
def test_csiadmm_converges_with_stragglers(small_problem, net6, scheme, K, S):
    """Coded ADMM converges while S ECNs straggle every iteration."""
    prob = small_problem
    if K != 3:
        ds = _planted(6000, 600, 5, 2, 0.05, seed=3, name="small")
        prob = allocate(ds, N=6, K=K)
    M = 60 if K == 3 else 80
    cfg = ADMMConfig(
        rho=1.0, c_tau=0.5, c_gamma=2.0, M=M, K=K, S=S, scheme=scheme
    )
    strag = StragglerModel(p_straggle=0.5, delay=1e-2)
    tr = run_incremental_admm(prob, net6, cfg, iters=3000, straggler=strag)
    assert tr.z_err[-1] < 3e-2


def test_csiadmm_matches_siadmm_gradient_path(small_problem, net6):
    """With zero stragglers, coded and uncoded iterates follow the same
    O(1/sqrt(k)) path (coded decode is exact, only batch size differs)."""
    cfg_u = ADMMConfig(rho=1.0, c_tau=0.5, c_gamma=2.0, M=30, K=3, S=0)
    # Coded with S=1 and M=60 has M_bar = 30 -> same effective batch size.
    cfg_c = ADMMConfig(
        rho=1.0, c_tau=0.5, c_gamma=2.0, M=60, K=3, S=1, scheme="cyclic"
    )
    tr_u = run_incremental_admm(small_problem, net6, cfg_u, iters=1500)
    tr_c = run_incremental_admm(small_problem, net6, cfg_c, iters=1500)
    assert abs(tr_u.z_err[-1] - tr_c.z_err[-1]) < 3e-2
    assert tr_c.z_err[-1] < 3e-2


def test_sublinear_rate_shape(small_problem, net6):
    """Relative error roughly follows O(1/sqrt(k)) (Theorem 2): the error at
    4x the iterations should be at most ~0.7x (ideally 0.5x)."""
    cfg = ADMMConfig(rho=1.0, c_tau=0.5, c_gamma=2.0, M=60, K=3, S=0)
    tr = run_incremental_admm(small_problem, net6, cfg, iters=4000)
    e1k, e4k = tr.z_err[999], tr.z_err[3999]
    assert e4k < 0.7 * e1k


def test_larger_batch_converges_faster(net6):
    """Paper Fig. 3(a)-(b): larger mini-batch size M gives better accuracy at
    the same iteration count (Theorem 2: variance term delta^2/M)."""
    ds = _planted(12000, 600, 5, 2, 0.5, seed=5, name="noisy")
    prob = allocate(ds, N=6, K=3)
    errs = {}
    for M in (6, 240):
        cfg = ADMMConfig(rho=1.0, c_tau=0.5, c_gamma=2.0, M=M, K=3, S=0)
        tr = run_incremental_admm(prob, net6, cfg, iters=2500)
        errs[M] = np.mean(tr.z_err[-500:])
    assert errs[240] < errs[6]


def test_straggler_tradeoff_mbar(small_problem, net6):
    """eq. (22): M_bar = M/(S+1)."""
    cfg = ADMMConfig(M=60, K=3, S=1, scheme="cyclic")
    assert cfg.M_bar == 30
    cfg = ADMMConfig(M=60, K=3, S=2, scheme="cyclic")
    assert cfg.M_bar == 20


def test_z_invariant(small_problem, net6):
    """z^k == mean_i (x_i^k - y_i^k / rho) after every iteration — the
    invariant that justifies the incremental z-update (4c)."""
    cfg = ADMMConfig(rho=2.0, c_tau=0.5, c_gamma=2.0, M=60, K=3, S=0)
    tr = run_incremental_admm(small_problem, net6, cfg, iters=500)
    # Recompute the invariant from the final state. y is not returned, but
    # z - mean(x) = -mean(y)/rho; verify via a fresh short run with rho
    # variation: the residual r = z - mean_i(x_i - y_i/rho) must be ~0.
    # We check the weaker observable version: consensus gap shrinks.
    gap = np.linalg.norm(tr.final_x - tr.final_z[None])
    gap0 = np.linalg.norm(tr.final_z) * np.sqrt(small_problem.N)
    assert gap < gap0  # agents have moved toward the token


def test_shortest_path_traversal(small_problem, net6):
    cfg = ADMMConfig(
        rho=1.0, c_tau=0.5, c_gamma=2.0, M=60, traversal="shortest_path"
    )
    tr = run_incremental_admm(small_problem, net6, cfg, iters=2000)
    assert tr.z_err[-1] < 5e-2


def test_config_validation():
    with pytest.raises(ValueError):
        ADMMConfig(M=50, K=3, S=1, scheme="cyclic").validate()  # 6 ∤ 50
    with pytest.raises(ValueError):
        ADMMConfig(M=60, K=3, S=1, scheme="uncoded").validate()
