"""Execution-tier and composition tests for the online controller.

The a-csI-ADMM kernel (DESIGN.md §15) runs a UCB1/EXP3 bandit over a
registered (code family, S, deadline) arm set INSIDE one jitted scan.
This file pins the systems contracts:

- serial == batched == sharded on adaptive grids, with the device arm
  -pull sequence bit-identical to the host numpy ``replay`` twin;
- ONE jitted executable per static group — arm schedules, rewards and
  bandit hyper-parameters are scan data, never statics;
- composition with the streaming Reduction carry (§12) and with the
  event-driven async/churn path (§13): no NaN leaks through dead-agent
  arm pulls;
- the reward surface itself (cap, bounds, monotonicity) and the loud
  config-time failures (empty/infeasible arm sets, unknown policy).

The controller-theory properties (regret, degenerate bit-identity,
permutation equivariance) live in ``test_control_properties.py``.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.control import ADAPTIVE_KERNEL, device_pulls
from repro.core.graph import make_network
from repro.core.problems import DATASETS, allocate
from repro.core.timing import TimingModel
from repro.experiments import Case, get_sweep, run_sweep
from repro.methods import Reduction, driver

ITERS = 30

# A feasible 3-cell slice of the code_frontier grid (K=6).
ARMS = (("cyclic", 1, None), ("cyclic", 2, None), ("approx", 2, 3e-4))


def _case(**kw) -> Case:
    kw.setdefault("method", "a-csI-ADMM")
    kw.setdefault("dataset", "synthetic")
    kw.setdefault("K", 6)
    kw.setdefault("M", 360)
    kw.setdefault("iters", ITERS)
    kw.setdefault("p_straggle", 0.3)
    kw.setdefault("delay", 5e-3)
    kw.setdefault("arms", ARMS)
    return Case(**kw)


def _materialize(case: Case):
    net = make_network(case.N, case.connectivity, seed=case.seed)
    prob = allocate(DATASETS[case.dataset](case.seed), case.N, case.K)
    return prob, net


# --------------------------------------------------------------------------
# Tier agreement + device/host pull parity
# --------------------------------------------------------------------------


def test_tier_agreement_serial_batched():
    """Serial and batched tiers agree on an adaptive grid covering both
    algorithms; one dispatch group per algorithm (the only static)."""
    cases = [
        _case(bandit=a, seed=s) for a in ("ucb1", "exp3") for s in range(2)
    ]
    serial = run_sweep(cases, mode="serial")
    batched = run_sweep(cases, mode="batched")
    assert batched.n_dispatches == 2
    for ts, tb in zip(serial.traces, batched.traces):
        np.testing.assert_allclose(
            tb.accuracy, ts.accuracy, rtol=1e-5, atol=1e-8
        )
        np.testing.assert_allclose(tb.final_z, ts.final_z, rtol=1e-5, atol=1e-8)
        np.testing.assert_array_equal(tb.sim_time, ts.sim_time)


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs a device mesh")
def test_tier_agreement_sharded():
    """The sharded tier reproduces the serial adaptive trajectory —
    same scan, different layout (DESIGN.md §9)."""
    cases = [_case(seed=s) for s in range(len(jax.devices()))]
    serial = run_sweep(cases, mode="serial")
    sharded = run_sweep(cases, mode="sharded")
    for ts, tsh in zip(serial.traces, sharded.traces):
        np.testing.assert_allclose(
            tsh.accuracy, ts.accuracy, rtol=1e-5, atol=1e-8
        )


@pytest.mark.parametrize("algo", ["ucb1", "exp3"])
def test_device_pulls_bit_match_host_replay(algo):
    """The DEVICE controller's realized arm-pull sequence equals the
    host numpy ``replay`` bit-for-bit — the determinism `prepare` relies
    on to realize the pull-dependent clock before dispatch."""
    case = _case(bandit=algo, iters=60)
    prob, net = _materialize(case)
    run = ADAPTIVE_KERNEL.config(case)
    tab = ADAPTIVE_KERNEL._arm_tables(prob, net, run, case.iters)
    dev = device_pulls(prob, net, run, case.iters)
    assert dev.dtype == np.int32
    np.testing.assert_array_equal(dev, tab["pulls"])
    # UCB1's deterministic round-robin init pulls every arm once first.
    if algo == "ucb1":
        assert list(dev[: len(ARMS)]) == list(range(len(ARMS)))


def test_device_pulls_requires_multiple_arms():
    case = _case(arms=(("cyclic", 1, None),))
    prob, net = _materialize(case)
    run = ADAPTIVE_KERNEL.config(case)
    with pytest.raises(ValueError, match="multi-arm"):
        device_pulls(prob, net, run, case.iters)


# --------------------------------------------------------------------------
# No retraces; composition with reductions and the async path
# --------------------------------------------------------------------------


def test_adaptive_schedules_cause_no_retrace():
    """Every seed / bandit hyper-parameter / arm-deadline value of an
    adaptive grid shares ONE jit trace: arm schedules, reward tables and
    [c, eta, gamma] ride the scan as data (PR-5/PR-8 pattern)."""
    driver._batch_fn.cache_clear()
    cases = [
        _case(seed=0),
        _case(seed=1),
        _case(seed=0, bandit_c=1.5),
        _case(seed=2, arms=(("cyclic", 1, None), ("cyclic", 2, None),
                            ("approx", 2, 1e-3))),
    ]
    res = run_sweep(cases, mode="batched")
    assert res.n_dispatches == 1
    assert driver._batch_fn.cache_info().currsize == 1


def test_adaptive_composes_with_streaming_reductions():
    """Adaptive runs flow through the in-scan Reduction fold (§12):
    O(grid) summaries on the realized pull-dependent clock, no
    materialized traces."""
    spec = dataclasses.replace(
        get_sweep("adaptive_frontier", iters=24, runs=1),
        reductions=Reduction(
            fields=("accuracy",), budgets=(0.5, 1.0), x="sim_time"
        ),
    )
    res = run_sweep(spec, mode="batched")
    assert res.traces == [] and res.reduced is not None
    for v in res.reduced.values():
        assert np.isfinite(v).all()


def test_adaptive_async_churn_no_nan_leak():
    """Bounded staleness + agent churn under the controller: dead-agent
    arm pulls stay finite (the masked combine of §11 plus the per-arm
    activity gate), and serial == batched holds on the async program."""
    case = _case(tau_max=2e-3, churn_rate=2.0, mttr=5e-3)
    serial = run_sweep([case], mode="serial").traces[0]
    batched = run_sweep([case], mode="batched").traces[0]
    assert np.isfinite(serial.accuracy).all()
    assert np.isfinite(serial.final_z).all()
    np.testing.assert_allclose(
        batched.accuracy, serial.accuracy, rtol=1e-5, atol=1e-8
    )


# --------------------------------------------------------------------------
# Reward surface + loud config-time failures
# --------------------------------------------------------------------------


def test_reward_surface_bounds_and_monotonicity():
    tm = TimingModel()
    cap = tm.reward_cap
    assert cap == tm.epsilon + tm.comm_hi
    dt = np.linspace(0.0, 2.0 * cap, 101)
    r = tm.reward(dt)
    assert r[0] == 1.0 and r[-1] == 0.0
    assert ((r >= 0.0) & (r <= 1.0)).all()
    assert (np.diff(r) <= 0.0).all()
    assert tm.reward(10.0 * cap) == 0.0


def test_config_rejects_bad_arm_sets_and_policies():
    with pytest.raises(ValueError, match="arm set is empty"):
        ADAPTIVE_KERNEL.config(_case(arms=()))
    with pytest.raises(ValueError, match="infeasible"):
        ADAPTIVE_KERNEL.config(_case(arms=(("approx", 0, None),)))
    with pytest.raises(ValueError, match="duplicate arm"):
        ADAPTIVE_KERNEL.config(
            _case(arms=(("cyclic", 1, None), ("cyclic", 1, None)))
        )
    with pytest.raises(ValueError, match="unknown bandit"):
        ADAPTIVE_KERNEL.config(_case(bandit="greedy"))


def test_config_rejects_exact_x():
    """The controller needs the stochastic coded x-update: an exact_x
    config has no code/deadline frontier to select on."""

    class _ExactCase:
        def __init__(self, case):
            self._case = case

        def __getattr__(self, name):
            return getattr(self._case, name)

        def admm_config(self):
            return dataclasses.replace(
                self._case.admm_config(), exact_x=True
            )

    with pytest.raises(ValueError, match="stochastic coded"):
        ADAPTIVE_KERNEL.config(_ExactCase(_case()))
