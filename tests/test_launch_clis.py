"""End-to-end CLI smoke: the train and serve launchers run as real
subprocesses on a reduced config (what an operator would actually type)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(mod, *args, timeout=400):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run(
        [sys.executable, "-m", mod, *args],
        capture_output=True, text=True, cwd=ROOT, env=env, timeout=timeout,
    )


@pytest.mark.slow
def test_train_cli_plain(tmp_path):
    r = _run(
        "repro.launch.train", "--arch", "qwen3-0.6b", "--smoke",
        "--steps", "3", "--batch", "4", "--seq", "32",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
    )
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "loss" in r.stdout
    assert any(f.startswith("step_") for f in os.listdir(tmp_path))


@pytest.mark.slow
def test_train_cli_consensus():
    r = _run(
        "repro.launch.train", "--arch", "qwen3-0.6b", "--smoke",
        "--mode", "consensus", "--agents", "2", "--ecns", "4",
        "--stragglers", "1", "--steps", "3", "--batch", "16", "--seq", "32",
    )
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "residual" in r.stdout


@pytest.mark.slow
def test_serve_cli():
    r = _run(
        "repro.launch.serve", "--arch", "qwen3-0.6b", "--smoke",
        "--batch", "2", "--prompt-len", "16", "--new-tokens", "4",
    )
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "ms/token" in r.stdout
