"""Hypothesis property tests for network topologies (paper §II, §V-A).

Kept separate from ``test_graph.py`` so topology tests run even when
``hypothesis`` is absent (optional dev dependency; see
``requirements-dev.txt``).
"""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import make_network


@settings(max_examples=20, deadline=None)
@given(N=st.integers(3, 30), eta=st.floats(0.1, 1.0), seed=st.integers(0, 99))
def test_property_network_connected_with_hamiltonian(N, eta, seed):
    """Assumption 1: connected and at least one Hamiltonian cycle."""
    net = make_network(N, eta, seed=seed)
    assert net.N == N
    # Hamiltonian order visits each agent exactly once...
    assert sorted(net.hamiltonian) == list(range(N))
    # ...along existing edges.
    A = net.adjacency
    for a in range(N):
        i, j = net.hamiltonian[a], net.hamiltonian[(a + 1) % N]
        assert A[i, j]
    # Shortest-path cycle visits every agent, along edges.
    assert set(net.shortest_path_cycle) == set(range(N))
    r = net.shortest_path_cycle
    for a in range(len(r)):
        assert A[r[a], r[(a + 1) % len(r)]]
