"""Hypothesis property tests for (K, R) MDS gradient coding (paper §III-B).

Kept separate from ``test_coding.py`` so the deterministic coding tests run
even when ``hypothesis`` is absent (it is an optional dev dependency; see
``requirements-dev.txt``).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.coding import cyclic_repetition_code, make_code


@settings(max_examples=25, deadline=None)
@given(
    K=st.integers(3, 8),
    S=st.integers(0, 3),
    seed=st.integers(0, 2**16),
)
def test_property_cyclic_any_R_of_K_decodes(K, S, seed):
    """Property: for any valid (K, S), any R responses recover the exact sum."""
    if S >= K:
        S = K - 1
    code = make_code("cyclic" if S else "uncoded", K, S, seed=seed)
    rng = np.random.default_rng(seed)
    g = rng.standard_normal((K, 3))
    msgs = code.encode(g)
    # random straggler pattern of size S
    dead = rng.choice(K, size=S, replace=False)
    alive = np.ones(K, dtype=bool)
    alive[dead] = False
    np.testing.assert_allclose(
        code.decode(msgs, alive), g.sum(0), rtol=1e-8, atol=1e-8
    )


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_property_decode_vector_in_rowspan(data):
    """a^T B == 1^T exactly (the defining MDS gradient-code identity)."""
    K = data.draw(st.integers(3, 7))
    S = data.draw(st.integers(1, min(3, K - 1)))
    seed = data.draw(st.integers(0, 1000))
    code = cyclic_repetition_code(K, S, seed=seed)
    rng = np.random.default_rng(seed)
    dead = rng.choice(K, size=S, replace=False)
    alive = np.ones(K, dtype=bool)
    alive[dead] = False
    a = code.decode_vector(alive)
    np.testing.assert_allclose(a @ code.B, np.ones(K), atol=1e-7)
    assert np.all(np.abs(a[~alive]) < 1e-12)  # only alive ECNs used
