"""Per-architecture smoke tests (reduced configs): one train step + a
prefill/decode consistency check, on CPU, asserting shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import get_model

B, S = 2, 64


def _batch(cfg, rng):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab, dtype=jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.modality == "vision_stub":
        batch["extra_embeds"] = jnp.ones((B, 16, cfg.d_model), cfg.jnp_dtype) * 0.01
    elif cfg.modality == "audio_stub":
        batch["extra_embeds"] = (
            jnp.ones((B, cfg.encoder_positions, cfg.d_model), cfg.jnp_dtype) * 0.01
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))

    @jax.jit
    def step(params, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        new = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
        return loss, new

    loss, new_params = step(params, batch)
    assert np.isfinite(float(loss)), arch
    leaves = jax.tree.leaves(new_params)
    assert all(np.all(np.isfinite(np.asarray(l, dtype=np.float32))) for l in leaves), arch
    # a second step must change the loss (params actually updated)
    loss2, _ = step(new_params, batch)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Prefill on S tokens then decode token S must equal prefill on S+1
    tokens — validates every cache layout (ring KV, SSM state, conv tail,
    RG-LRU state, whisper cross-KV)."""
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    rng = jax.random.key(7)
    T = 33
    tokens = jax.random.randint(rng, (B, T), 0, cfg.vocab, dtype=jnp.int32)
    extra = _batch(cfg, rng).get("extra_embeds")

    kwargs = {} if extra is None else {"extra_embeds": extra}
    # extra_slots=1 reserves one decode slot in ring-buffered KV caches
    # (state caches accept and ignore it).
    logits_a, cache = model.prefill(
        params, tokens[:, : T - 1], extra_slots=1, **kwargs
    )
    assert logits_a.shape == (B, 1, cfg.vocab)
    logits_b, cache2 = model.decode(params, cache, tokens[:, T - 1 :])
    logits_full, _ = model.prefill(params, tokens, **kwargs)
    np.testing.assert_allclose(
        np.asarray(logits_b, np.float32),
        np.asarray(logits_full, np.float32),
        rtol=2e-2,
        atol=2e-3,
    )
    assert int(cache2["len"]) == T
    assert np.all(np.isfinite(np.asarray(logits_b, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_is_exact_assignment(arch):
    """The full CONFIG matches the assigned table exactly."""
    from repro.configs import get_config

    cfg = get_config(arch)
    expected = {
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab)
    assert got == expected
    if arch == "mixtral-8x22b":
        assert (cfg.n_experts, cfg.experts_per_token) == (8, 2)
        assert cfg.sliding_window is not None
    if arch == "phi3.5-moe-42b-a6.6b":
        assert (cfg.n_experts, cfg.experts_per_token) == (16, 2)
    if arch == "mamba2-1.3b":
        assert cfg.ssm_state == 128
    if arch == "recurrentgemma-9b":
        assert cfg.attn_every == 3  # 1:2 local-attn : RG-LRU
    if arch == "qwen3-0.6b":
        assert cfg.qk_norm
    if arch == "qwen2-vl-72b":
        assert cfg.mrope_sections is not None
    if arch == "whisper-medium":
        assert cfg.encoder_layers == 24


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mixtral-8x22b"])
def test_pallas_attention_backend_matches_jnp(arch):
    """cfg.attn_impl="pallas" routes the model through the flash-attention
    kernel (interpret mode on CPU) and must match the jnp path."""
    import dataclasses

    cfg = get_smoke_config(arch)
    cfg_p = dataclasses.replace(cfg, attn_impl="pallas")
    batch = _batch(cfg, jax.random.key(2))
    params = get_model(cfg).init(jax.random.key(0))
    l1, _ = get_model(cfg).loss(params, batch)
    l2, _ = get_model(cfg_p).loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=5e-3)


def test_pallas_ssm_backend_matches_jnp():
    import dataclasses

    cfg = get_smoke_config("mamba2-1.3b")
    cfg_p = dataclasses.replace(cfg, ssm_impl="pallas", ssm_chunk=32)
    cfg = dataclasses.replace(cfg, ssm_chunk=32)
    batch = _batch(cfg, jax.random.key(2))
    params = get_model(cfg).init(jax.random.key(0))
    l1, _ = get_model(cfg).loss(params, batch)
    l2, _ = get_model(cfg_p).loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=5e-3)


def test_pallas_rglru_backend_matches_jnp():
    import dataclasses

    cfg = get_smoke_config("recurrentgemma-9b")
    cfg_p = dataclasses.replace(cfg, ssm_impl="pallas")
    batch = _batch(cfg, jax.random.key(2))
    params = get_model(cfg).init(jax.random.key(0))
    l1, _ = get_model(cfg).loss(params, batch)
    l2, _ = get_model(cfg_p).loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=5e-3)
