"""Distributed runtime tests (math on CPU; lowering is covered by the
dry-run subprocess test in test_dryrun.py).

The consensus train_step is a pure function — we drive it directly with a
stub model and verify the csI-ADMM equations, the coded-gradient row-weight
algebra, and straggler invariance (any R-of-K alive set decodes the same
gradient).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import ConsensusConfig, ConsensusRuntime, auto_spec, AxisLayout
from repro.distributed.sharding import batch_specs
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# stub model: per-row quadratic loss 0.5 ||w - t_b||^2 (grad linear in rows)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QuadModel:
    p: int = 4

    def init(self, rng):
        return {"w": jnp.zeros((self.p,), jnp.float32)}

    def loss(self, params, batch):
        t = batch["tokens"].astype(jnp.float32)  # (B, p) targets
        d = params["w"][None] - t
        row_loss = 0.5 * jnp.sum(d * d, axis=-1)  # (B,)
        w = batch.get("loss_weights")
        if w is None:
            loss = row_loss.mean()
        else:
            loss = jnp.sum(w * row_loss)
        return loss, {"nll": loss, "moe_aux": jnp.zeros(())}


def _dummy_mesh():
    return jax.make_mesh((1, 1, 1), ("agent", "data", "model"))


def _coded_batch(rng, A, K, S, P_rows, p, support):
    """Coded-allocated batch: partition t's rows replicated on the S+1 ECNs
    whose supports contain t, laid out (A, K, S+1, P_rows) row-major."""
    distinct = rng.standard_normal((A, K, P_rows, p)).astype(np.float32)
    rows = np.zeros((A, K, S + 1, P_rows, p), np.float32)
    for j in range(K):
        for u, t in enumerate(support[j]):
            rows[:, j, u] = distinct[:, t]
    return distinct, rows.reshape(A * K * (S + 1) * P_rows, p)


@pytest.mark.parametrize("scheme,K,S", [("cyclic", 4, 1), ("fractional", 4, 1), ("cyclic", 5, 2)])
def test_decoded_gradient_invariant_to_stragglers(scheme, K, S):
    """Any R-of-K alive pattern yields the same decoded gradient == the
    uncoded mean gradient over distinct rows (MDS exactness, eq. 6)."""
    A, P_rows, p = 2, 3, 4
    cfg = ConsensusConfig(n_agents=A, K=K, S=S, scheme=scheme)
    rt = ConsensusRuntime(QuadModel(p), cfg, _dummy_mesh())
    code = cfg.code()
    sup = [code.support(j) for j in range(K)]
    rng = np.random.default_rng(0)
    distinct, flat = _coded_batch(rng, A, K, S, P_rows, p, sup)
    w0 = jnp.zeros((p,), jnp.float32)
    # expected: mean over the distinct rows of (w - t) = -mean(t)
    expect = -distinct.reshape(A, K * P_rows, p).mean(axis=1)

    rows_per_agent = flat.shape[0] // A
    batch_rows = jnp.asarray(flat).reshape(A, rows_per_agent, p)

    def decoded_grad(alive_np):
        w = rt.row_weights(jnp.asarray(alive_np), rows_per_agent)  # (A, rows)
        g = []
        for a in range(A):
            g.append(-(w[a][:, None] * batch_rows[a]).sum(0) + w[a].sum() * w0)
        return np.stack([np.asarray(x) for x in g])

    all_alive = np.ones((A, K), bool)
    g_full = decoded_grad(all_alive)
    np.testing.assert_allclose(g_full, expect, rtol=1e-5, atol=1e-6)
    # every pattern with exactly S dead ECNs decodes identically
    import itertools

    for dead in itertools.combinations(range(K), S):
        alive = np.ones((A, K), bool)
        alive[:, list(dead)] = False
        g = decoded_grad(alive)
        np.testing.assert_allclose(g, expect, rtol=1e-4, atol=1e-5)


def test_incremental_mode_updates_one_agent():
    A, K, S, P_rows, p = 4, 4, 1, 2, 3
    cfg = ConsensusConfig(n_agents=A, K=K, S=S, scheme="fractional", mode="incremental")
    rt = ConsensusRuntime(QuadModel(p), cfg, _dummy_mesh())
    code = cfg.code()
    sup = [code.support(j) for j in range(K)]
    rng = np.random.default_rng(1)
    _, flat = _coded_batch(rng, A, K, S, P_rows, p, sup)
    state = rt.init_state(jax.random.key(0))
    batch = {"tokens": jnp.asarray(flat)}
    alive = jnp.ones((A, K), bool)
    new, metrics = rt.train_step(state, batch, alive)
    assert int(new["k"]) == 1
    # active agent for k=1 is (k-1) % A = 0
    dx = np.asarray(new["x"]["w"]) - np.asarray(state["x"]["w"])
    changed = np.abs(dx).sum(axis=1) > 0
    assert changed[0] and not changed[1:].any()
    dy = np.asarray(new["y"]["w"]) - np.asarray(state["y"]["w"])
    assert (np.abs(dy).sum(axis=1) > 0)[0] and not (np.abs(dy).sum(axis=1) > 0)[1:].any()


@pytest.mark.parametrize("mode", ["incremental", "parallel"])
def test_consensus_converges_quadratic(mode):
    """z and all x_a converge to the average target (the consensus optimum
    of sum_a 0.5||w - mu_a||^2) under the Theorem-2 schedules."""
    A, K, S, P_rows, p = 2, 4, 1, 4, 3
    cfg = ConsensusConfig(
        n_agents=A, K=K, S=S, scheme="cyclic", mode=mode,
        rho=1.0, c_tau=0.05, c_gamma=1.0,
    )
    rt = ConsensusRuntime(QuadModel(p), cfg, _dummy_mesh())
    code = cfg.code()
    sup = [code.support(j) for j in range(K)]
    rng = np.random.default_rng(2)
    distinct, flat = _coded_batch(rng, A, K, S, P_rows, p, sup)
    target = distinct.reshape(A, -1, p).mean(axis=(0, 1))

    step = jax.jit(rt.train_step)
    state = rt.init_state(jax.random.key(0))
    batch = {"tokens": jnp.asarray(flat)}
    rng2 = np.random.default_rng(3)
    iters = 600 if mode == "incremental" else 300
    for _ in range(iters):
        # random straggler: drop one ECN per agent with prob 1/2
        alive = np.ones((A, K), bool)
        for a in range(A):
            if rng2.random() < 0.5:
                alive[a, rng2.integers(K)] = False
        state, metrics = step(state, batch, jnp.asarray(alive))
    z = np.asarray(state["z"]["w"])
    np.testing.assert_allclose(z, target, rtol=0.05, atol=0.05)
    x = np.asarray(state["x"]["w"])
    np.testing.assert_allclose(x, np.broadcast_to(target, x.shape), rtol=0.1, atol=0.1)
    assert float(metrics["consensus_residual"]) < 0.2


# ---------------------------------------------------------------------------
# sharding inference
# ---------------------------------------------------------------------------


def test_auto_spec_rules():
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1, 1),
        ("agent", "data", "model"),
    )
    # pretend axis sizes via a fake layout
    layout = AxisLayout(mesh, data=("data",), model="model")
    layout.data_size, layout.model_size = 16, 16

    # (L, D, F): TP on F, FSDP on D, layer dim untouched
    assert auto_spec((56, 6144, 16384), layout) == P(None, "data", "model")
    # embedding (V, D): data on V, model on D
    assert auto_spec((32768, 4096), layout) == P("data", "model")
    # indivisible vocab (mamba2): V=50280 % 16 != 0 -> replicated on that dim
    assert auto_spec((50280, 2048), layout) == P(None, "model")
    # 1D stays replicated
    assert auto_spec((2048,), layout) == P("model")
    # norm smaller than axis
    assert auto_spec((7,), layout) == P(None)
    # consensus x with leading agent axis
    assert auto_spec((2, 56, 6144, 16384), layout, leading=("agent",)) == P(
        "agent", None, "data", "model"
    )
    # kv cache (L, B, C, KV, hd): data on B, model on hd
    assert auto_spec((56, 128, 32768, 8, 128), layout) == P(
        None, "data", None, None, "model"
    )


def test_batch_specs():
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1, 1),
        ("agent", "data", "model"),
    )
    layout = AxisLayout(mesh, data=("data",), model="model", agent="agent")
    layout.data_size, layout.agent_size = 8, 2
    batch = {
        "tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
        "labels": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
    }
    specs = batch_specs(batch, layout)
    assert specs["tokens"] == P(("agent", "data"), None)


def test_moe_grouped_dispatch_equivalence():
    """groups>1 dispatch == global dispatch when capacity doesn't bind
    (the §Perf shard-local MoE variant must not change the math)."""
    import jax
    from repro.models.layers import moe_apply

    T, D, E, F, k = 64, 16, 4, 32, 2
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (T, D))
    p = {
        "router": jax.random.normal(ks[1], (D, E)),
        "w_gate": jax.random.normal(ks[2], (E, D, F)) * 0.1,
        "w_up": jax.random.normal(ks[3], (E, D, F)) * 0.1,
        "w_down": jax.random.normal(ks[4], (E, F, D)) * 0.1,
    }
    o1, _ = moe_apply(x, p, E, k, 8.0, groups=1)
    o4, _ = moe_apply(x, p, E, k, 8.0, groups=4)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o4), atol=1e-6)
