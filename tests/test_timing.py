"""Unified timing model tests (DESIGN.md §10).

The contract: EVERY registered method kernel emits an honest simulated
wall-clock — strictly increasing, positive ``sim_time`` (the guard that
keeps future methods from re-introducing the ``zeros(iters)``
placeholder) — and the time-axis reduction turns those clocks into a
seed-averaged accuracy-vs-running-time curve that all execution tiers
agree on elementwise.
"""

import jax
import numpy as np
import pytest

from repro.core.admm import ADMMConfig, make_schedule
from repro.core.coding import make_code
from repro.core.graph import make_network
from repro.core.problems import DATASETS, allocate
from repro.core.timing import StragglerModel, TimingModel
from repro.experiments import (
    Case,
    get_sweep,
    reduce_mean,
    resample_runs,
    run_sweep,
)
from repro.experiments.sweep import METHODS
from repro.methods import get_kernel

ITERS = 40


def _case(method: str, **kw) -> Case:
    incremental = method not in ("D-ADMM", "DGD", "EXTRA", "W-ADMM")
    kw.setdefault("M", 36 if incremental else 33)
    if method == "csI-ADMM":
        kw.setdefault("S", 1)
        kw.setdefault("scheme", "cyclic")
    if method == "a-csI-ADMM":
        kw.setdefault(
            "arms", (("cyclic", 1, None), ("approx", 1, 3e-4))
        )
    return Case(method=method, dataset="usps", N=5, K=3, iters=ITERS, **kw)


def _prepared(case: Case):
    kernel = get_kernel(case.method)
    net = make_network(case.N, case.connectivity, seed=case.seed)
    prob = allocate(DATASETS[case.dataset](case.seed), case.N, case.K)
    return kernel.prepare(prob, net, kernel.config(case), case.iters)


# -------------------------------------------------------------------------
# the zeros(iters) guard: every kernel's clock is real
# -------------------------------------------------------------------------


@pytest.mark.parametrize("method", sorted(METHODS))
def test_every_kernel_emits_increasing_positive_time(method):
    """sim_time and comm_cost are cumulative: positive and strictly
    increasing for EVERY registered kernel — no constant-zero placeholders."""
    prep = _prepared(_case(method))
    for field in ("sim_time", "comm"):
        series = np.asarray(getattr(prep, field))
        assert series.shape == (ITERS,), (method, field)
        assert series[0] > 0, (method, field)
        assert (np.diff(series) > 0).all(), (method, field)


def test_gossip_round_dominates_incremental_hop():
    """A gossip round waits for the slowest of N agents plus serialized
    neighbor transfers — per iteration it must cost at least as much as
    any single agent's compute draw, and in expectation more than the
    single-agent walk step."""
    si = _prepared(_case("sI-ADMM")).sim_time[-1]
    dgd = _prepared(_case("DGD")).sim_time[-1]
    assert dgd > si * 0.5  # same order of magnitude: one unified clock
    model = TimingModel(p_straggle=0.0)
    net = make_network(6, 0.6, seed=0)
    rng = np.random.default_rng(0)
    rounds = model.gossip_round_times(net, 500, rng)
    # every round >= base_lo compute + max-degree * comm_lo transfers
    floor = model.base_lo + net.degree().max() * model.comm_lo
    assert (rounds >= floor).all()


# -------------------------------------------------------------------------
# uncoded straggler fallback (satellite bugfix)
# -------------------------------------------------------------------------


def test_uncoded_fallback_records_true_wait():
    """When NO ECN beats epsilon, the agent waits out the fastest ECN —
    the recorded response must be that (> epsilon) wait, not the cap."""
    cfg = ADMMConfig(M=36, K=3, scheme="uncoded")
    net = make_network(5, 0.5, seed=0)
    # base compute 10-20x the cap: every iteration falls back
    model = TimingModel(
        base_lo=1e-3, base_hi=2e-3, p_straggle=0.0, epsilon=1e-4
    )
    sched = make_schedule(
        cfg, net, make_code("uncoded", 3, 0), model, 200, b=36 * 3
    )
    assert (sched["resp_time"] > model.epsilon).all()
    # the wait is exactly the fastest ECN's response on every fallback row
    rng = np.random.default_rng(cfg.seed + 1)
    ecn_t = model.sample_ecn_times(200, cfg.K, rng)
    np.testing.assert_allclose(sched["resp_time"], ecn_t.min(axis=1))
    # ...and the decode weights use only that fastest ECN (weight K)
    assert (np.sort(sched["decode"], axis=1)[:, :-1] == 0).all()
    assert (sched["decode"].max(axis=1) == cfg.K).all()


def test_uncoded_cap_still_applies_when_someone_responds():
    cfg = ADMMConfig(M=36, K=3, scheme="uncoded")
    net = make_network(5, 0.5, seed=0)
    model = TimingModel(p_straggle=0.5, delay=1e-2, epsilon=2e-3)
    sched = make_schedule(
        cfg, net, make_code("uncoded", 3, 0), model, 500, b=36 * 3
    )
    rng = np.random.default_rng(cfg.seed + 1)
    ecn_t = model.sample_ecn_times(500, cfg.K, rng)
    responded = (ecn_t <= model.epsilon).any(axis=1)
    assert responded.any() and not responded.all()
    assert (sched["resp_time"][responded] <= model.epsilon).all()
    assert (
        sched["resp_time"][~responded] == ecn_t[~responded].min(axis=1)
    ).all()


# -------------------------------------------------------------------------
# heterogeneous fleet knobs
# -------------------------------------------------------------------------


def test_speed_classes_scale_worker_times():
    rng_hom = np.random.default_rng(7)
    rng_het = np.random.default_rng(7)
    hom = TimingModel(p_straggle=0.0).sample_ecn_times(300, 4, rng_hom)
    het = TimingModel(
        p_straggle=0.0, speed_classes=(1.0, 3.0)
    ).sample_ecn_times(300, 4, rng_het)
    # round-robin assignment: workers 0/2 untouched, workers 1/3 3x slower
    np.testing.assert_allclose(het[:, ::2], hom[:, ::2])
    np.testing.assert_allclose(het[:, 1::2], 3.0 * hom[:, 1::2])


def test_shifted_exp_response_floor_and_tail():
    model = TimingModel(p_straggle=0.0, response="shifted_exp")
    t = model.sample_ecn_times(2000, 3, np.random.default_rng(0))
    assert (t >= model.base_lo).all()
    # exponential tail: some draws exceed the uniform model's hard cap
    assert (t > model.base_hi).any()
    mean = model.base_lo + (model.base_hi - model.base_lo)
    assert t.mean() == pytest.approx(mean, rel=0.1)


def test_timing_model_validation():
    with pytest.raises(ValueError, match="unknown response"):
        TimingModel(response="gaussian")
    with pytest.raises(ValueError, match="speed_classes"):
        TimingModel(speed_classes=())
    with pytest.raises(ValueError, match="speed_classes"):
        TimingModel(speed_classes=(1.0, -2.0))
    # the paper-era name is the same class, homogeneous-uniform defaults
    assert StragglerModel is TimingModel


def test_hetero_slowdown_reaches_the_admm_clock():
    """A uniformly 4x slower fleet must produce a ~4x slower response
    path end-to-end through Case -> kernel.prepare (p_straggle=0 so the
    additive straggler delay doesn't blur the ratio)."""
    fast = _prepared(_case("csI-ADMM", S=1, p_straggle=0.0))
    slow = _prepared(
        _case("csI-ADMM", S=1, p_straggle=0.0, speed_classes=(4.0,))
    )
    assert slow.sim_time[-1] > 2.0 * fast.sim_time[-1]


# -------------------------------------------------------------------------
# deadline-aware decode (DESIGN.md §11)
# -------------------------------------------------------------------------


def _coded_schedule(scheme: str, K: int, S: int, model: TimingModel, iters=400):
    cfg = ADMMConfig(M=(S + 1) * K * 4, K=K, S=S, scheme=scheme)
    net = make_network(5, 0.5, seed=0)
    code = make_code(scheme, K, S, seed=cfg.seed)
    sched = make_schedule(cfg, net, code, model, iters, b=cfg.M * 2)
    rng = np.random.default_rng(cfg.seed + 1)
    ecn_t = model.sample_ecn_times(iters, K, rng)
    return code, sched, ecn_t


def test_deadline_decode_records_deadline_not_ecn_wait():
    """The satellite guard: iterations that decode at the deadline must
    record the DEADLINE as their response time — not the R-th (slowest
    counted) ECN wait — and their decode vectors must be supported on
    exactly the ECNs that had arrived by the deadline."""
    model = TimingModel(p_straggle=0.3, delay=5e-3, deadline=3e-4)
    code, sched, ecn_t = _coded_schedule("approx", 6, 2, model)
    arrived = ecn_t <= model.deadline
    n_arr = arrived.sum(axis=1)
    order = np.sort(ecn_t, axis=1)
    t_exact = order[:, code.R - 1]
    fired = (n_arr >= code.min_responses) & (n_arr < code.R)
    assert fired.any() and not fired.all()  # both paths exercised
    np.testing.assert_allclose(
        sched["resp_time"][fired], model.deadline
    )
    # the deadline wait is strictly shorter than the exact-decode wait
    assert (model.deadline < t_exact[fired]).all()
    # non-deadline rows keep the epsilon-capped R-th fastest response
    np.testing.assert_allclose(
        sched["resp_time"][~fired],
        np.minimum(t_exact[~fired], model.epsilon),
    )
    # decode supported on the arrived set only, alive mask recorded
    np.testing.assert_array_equal(sched["alive"][fired], arrived[fired])
    assert (sched["decode"][fired][~arrived[fired]] == 0).all()


def test_deadline_below_rmin_falls_back_to_exact_wait():
    """A deadline nobody can meet (shorter than every base draw) never
    fires: every iteration decodes exactly at the R-th response."""
    model = TimingModel(p_straggle=0.3, delay=5e-3, deadline=1e-6)
    code, sched, ecn_t = _coded_schedule("approx", 6, 2, model)
    t_exact = np.sort(ecn_t, axis=1)[:, code.R - 1]
    np.testing.assert_allclose(
        sched["resp_time"], np.minimum(t_exact, model.epsilon)
    )


def test_deadline_above_epsilon_never_fires():
    """'Whichever fires first' also holds against the epsilon cap: a
    deadline armed ABOVE epsilon can never beat the exact path's capped
    wait, so it must not fire (firing would record a LONGER wait plus a
    decode error)."""
    model = TimingModel(
        p_straggle=0.3, delay=5e-3, epsilon=1e-3, deadline=2e-3
    )
    exact = TimingModel(p_straggle=0.3, delay=5e-3, epsilon=1e-3)
    _, s_dl, _ = _coded_schedule("approx", 6, 2, model)
    _, s_ex, _ = _coded_schedule("approx", 6, 2, exact)
    for f in ("resp_time", "decode", "alive"):
        np.testing.assert_array_equal(s_dl[f], s_ex[f])
    assert (s_dl["resp_time"] <= model.epsilon).all()


def test_deadline_noop_for_exact_families():
    """Exact-only families (min_responses == R) ignore the deadline: the
    schedule is bit-identical with and without it."""
    with_dl = TimingModel(p_straggle=0.3, deadline=3e-4)
    without = TimingModel(p_straggle=0.3)
    for scheme in ("cyclic", "fractional"):
        _, s1, _ = _coded_schedule(scheme, 6, 2, with_dl)
        _, s2, _ = _coded_schedule(scheme, 6, 2, without)
        for f in ("resp_time", "decode", "alive"):
            np.testing.assert_array_equal(s1[f], s2[f], err_msg=scheme)


def test_deadline_shortens_admm_clock_end_to_end():
    """Case -> kernel.prepare: a deadline-decoding run's cumulative
    sim_time is strictly below the exact-decode run's (same draws)."""
    base = dict(scheme="approx", S=1, p_straggle=0.3, delay=5e-3)
    exact = _prepared(_case("csI-ADMM", **base))
    dl = _prepared(_case("csI-ADMM", **base, deadline=3e-4))
    assert dl.sim_time[-1] < exact.sim_time[-1]


def test_timing_model_deadline_validation():
    with pytest.raises(ValueError, match="deadline"):
        TimingModel(deadline=0.0)
    with pytest.raises(ValueError, match="deadline"):
        TimingModel(deadline=-1e-3)
    assert TimingModel(deadline=None).deadline is None


def test_code_frontier_single_dispatch_and_tier_agreement():
    """Acceptance criterion: the code_frontier grid is ONE dispatch, and
    serial/batched/sharded agree elementwise on the sim_time-axis
    reduction (the sweep's declared headline axis)."""
    spec = get_sweep("code_frontier", iters=40, runs=2)
    batched = run_sweep(spec, mode="batched")
    assert batched.n_dispatches == 1
    assert len(batched.cases) == 20
    modes = [batched, run_sweep(spec, serial=True)]
    if len(jax.devices()) > 1:
        modes.append(run_sweep(spec, mode="sharded"))
    reds = [
        reduce_mean(r, by=("scheme", "S", "deadline"), x="sim_time",
                    n_points=48)
        for r in modes
    ]
    assert len(reds[0]) == 10
    for key, r in reds[0].items():
        assert r["n"] == 2
        assert np.isfinite(r["mean"]).all(), key
        for other in reds[1:]:
            np.testing.assert_allclose(
                r["mean"], other[key]["mean"], rtol=1e-5, atol=1e-5,
                err_msg=f"tiers disagree on {key}",
            )


# -------------------------------------------------------------------------
# time-axis reduction + tier agreement (acceptance criterion)
# -------------------------------------------------------------------------


def test_resample_runs_step_function():
    xs = np.array([[1.0, 2.0, 4.0], [1.0, 3.0, 5.0]])
    ys = np.array([[9.0, 8.0, 7.0], [6.0, 5.0, 4.0]])
    grid, vals = resample_runs(xs, ys, n_points=5)
    np.testing.assert_allclose(grid, [0.0, 1.0, 2.0, 3.0, 4.0])
    # run 0: first value held before t=1, steps at 1/2/4
    np.testing.assert_allclose(vals[0], [9.0, 9.0, 8.0, 8.0, 7.0])
    np.testing.assert_allclose(vals[1], [6.0, 6.0, 6.0, 5.0, 5.0])
    with pytest.raises(ValueError, match="R, iters"):
        resample_runs(xs[0], ys[0])


def test_fig3e_runtime_reduction_and_tier_agreement():
    """The acceptance contract: fig3e_runtime reduces to a monotone
    per-method accuracy-vs-time curve via reduce_mean(x="sim_time"), and
    serial/batched(/sharded) tiers agree on it elementwise."""
    spec = get_sweep("fig3e_runtime", iters=60, runs=2)
    batched = run_sweep(spec, mode="batched")
    serial = run_sweep(spec, serial=True)
    modes = [batched, serial]
    if len(jax.devices()) > 1:
        modes.append(run_sweep(spec, mode="sharded"))
    reds = [
        reduce_mean(r, by=("method",), x="sim_time", n_points=64)
        for r in modes
    ]
    assert set(reds[0]) == {
        (m,) for m in ("sI-ADMM", "W-ADMM", "D-ADMM", "DGD", "EXTRA")
    }
    for key, r in reds[0].items():
        assert r["n"] == 2
        grid = r["x"]
        assert grid[0] == 0.0 and (np.diff(grid) > 0).all(), key
        assert np.isfinite(r["mean"]).all(), key
        # relative error starts near 1 and must have improved by budget
        assert r["mean"][-1] < r["mean"][0], key
        for other in reds[1:]:
            np.testing.assert_allclose(
                r["mean"], other[key]["mean"], rtol=1e-5, atol=1e-5,
                err_msg=f"tiers disagree on {key}",
            )
            np.testing.assert_allclose(grid, other[key]["x"], rtol=1e-12)


def test_gossip_timing_deterministic_per_seed():
    """Same Case -> same clock (host draws are seeded); different seeds
    -> different clocks (independent straggler realizations)."""
    a = _prepared(_case("EXTRA", seed=0)).sim_time
    b = _prepared(_case("EXTRA", seed=0)).sim_time
    c = _prepared(_case("EXTRA", seed=1)).sim_time
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_compressed_token_ships_faster_link():
    """cq-sI-ADMM's compressed hops scale LINK time by their true bit
    cost, while the ECN response term is untouched — total simulated
    time sits strictly between response-only and the dense-token clock."""
    dense = _prepared(_case("sI-ADMM", p_straggle=0.0))
    comp = _prepared(
        _case("cq-sI-ADMM", p_straggle=0.0, compressor="topk", frac=0.25)
    )
    assert comp.sim_time[-1] < dense.sim_time[-1]


def test_hetero_grid_single_dispatch():
    """Speed classes touch only the host-side clock, so the whole
    heterogeneity grid still batches into ONE dispatch — and a slower
    mix can only push every matched (S, scheme, seed) arm's clock out
    (same base draws, scaled up)."""
    spec = get_sweep("hetero_grid", iters=8, runs=1)
    result = run_sweep(spec)
    assert len(result.cases) == 15
    assert result.n_dispatches == 1
    finals = {
        (c.speed_classes, c.S, c.scheme): t.sim_time[-1]
        for c, t in zip(result.cases, result.traces)
    }
    pairs = [
        (finals[((1.0,), S, scheme)], finals[((1.0, 1.0, 4.0), S, scheme)])
        for (sc, S, scheme) in finals if sc == (1.0,)
    ]
    assert all(hom <= het for hom, het in pairs)
    assert any(hom < het for hom, het in pairs)
