"""Trace-contract analyzer tests (DESIGN.md §14, ISSUE 9).

Two layers:

- the AST linter against the fixture corpus (`tests/fixtures/lint`):
  each known-bad snippet fires exactly its rule, the clean fixture and
  the shipped `src/` tree fire nothing;
- the jaxpr-audit gate logic (`compare_report`) on synthetic reports —
  growth fails, shrinkage notes, callbacks/expect_pallas/f64 fail
  unconditionally — plus one real lowering of the cheapest audit grid
  checked against the committed `benchmarks/trace_audit.json`.
"""

import copy
import json
import pathlib
import sys

import pytest

from repro.analysis import RULES, lint_paths
from repro.analysis import traceaudit

ROOT = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = ROOT / "tests" / "fixtures" / "lint"

sys.path.insert(0, str(ROOT / "tools"))

import trace_lint  # noqa: E402


# --------------------------------------------------------------------------
# AST linter: fixture corpus
# --------------------------------------------------------------------------

FIXTURE_RULES = {
    "host_rng_in_step.py": "host-rng-in-device-code",
    "jnp_in_prepare.py": "device-array-in-host-prepare",
    "traced_branch_in_step.py": "traced-python-control-flow",
    "callback_in_step.py": "callback-in-scan-body",
    "unfrozen_spec.py": "spec-dataclass-not-frozen",
    "missing_statics_key.py": "statics-key-not-in-signature",
}


@pytest.mark.parametrize("fname,rule", sorted(FIXTURE_RULES.items()))
def test_fixture_fires_exactly_its_rule(fname, rule):
    findings = lint_paths([FIXTURES / fname])
    assert findings, f"{fname} produced no findings"
    assert {f.rule for f in findings} == {rule}


def test_every_rule_has_a_fixture():
    """The corpus stays in lockstep with the rule set: adding a rule
    without a known-bad fixture fails here."""
    assert set(FIXTURE_RULES.values()) == set(RULES)


def test_clean_fixture_has_zero_findings():
    assert lint_paths([FIXTURES / "clean.py"]) == []


def test_shipped_tree_is_clean():
    """src/ carries zero violations — the tree the rules were fixed
    against (SweepSpec was frozen by this PR)."""
    assert lint_paths([ROOT / "src"], root=ROOT) == []


def test_findings_are_located_and_printable():
    findings = lint_paths([FIXTURES / "host_rng_in_step.py"])
    f = findings[0]
    assert f.path.endswith("host_rng_in_step.py") and f.line > 0
    assert f.rule in str(f) and str(f.line) in str(f)


def test_linted_corpus_as_a_whole_fires_all_rules():
    """Lint the whole corpus in one call (cross-file statics-key union
    must not suppress the missing-key fixture: `ghost_gain` is produced
    nowhere in the corpus either)."""
    findings = lint_paths([FIXTURES])
    assert {f.rule for f in findings} == set(RULES)


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------


def test_cli_nonzero_on_each_fixture(capsys):
    for fname in FIXTURE_RULES:
        rc = trace_lint.main(["--ast-only", str(FIXTURES / fname)])
        out = capsys.readouterr().out
        assert rc == 1, fname
        assert FIXTURE_RULES[fname] in out


def test_cli_zero_on_src(capsys):
    assert trace_lint.main(["--ast-only"]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_flag_contradiction():
    with pytest.raises(SystemExit):
        trace_lint.main(["--ast-only", "--audit-only"])


# --------------------------------------------------------------------------
# Jaxpr audit: gate logic on synthetic reports
# --------------------------------------------------------------------------


def _entry(groups=1, pallas=1, callbacks=0, demotions=1, f64=True):
    return {
        "groups": groups,
        "expect_pallas": True,
        "signatures": {
            "('admm', 5)": {
                "pallas_calls": pallas,
                "callbacks": callbacks,
                "demotions": demotions,
                "f64_outputs": f64,
                "out_dtypes": ["float64"] if f64 else ["float32"],
            }
        },
    }


def test_gate_passes_on_identical_reports():
    fresh = {"admm_coded": _entry()}
    fails, _ = traceaudit.compare_report(fresh, copy.deepcopy(fresh))
    assert fails == []


def test_gate_fails_on_callbacks_unconditionally():
    fresh = {"admm_coded": _entry(callbacks=2)}
    fails, _ = traceaudit.compare_report(fresh, None)
    assert any("callback" in f for f in fails)


def test_gate_fails_on_lost_pallas_path():
    fresh = {"admm_coded": _entry(pallas=0)}
    fails, _ = traceaudit.compare_report(fresh, None)
    assert any("pallas_call" in f for f in fails)


def test_gate_fails_on_f32_outputs():
    fresh = {"admm_coded": _entry(f64=False)}
    fails, _ = traceaudit.compare_report(fresh, None)
    assert any("demoted" in f for f in fails)


def test_gate_fails_on_group_growth():
    base = {"admm_coded": _entry()}
    fresh = {"admm_coded": _entry(groups=3)}
    fails, _ = traceaudit.compare_report(fresh, base)
    assert any("grew 1 -> 3" in f for f in fails)
    # growth also breaks the grid's declared expect_groups
    assert any("declares 1" in f for f in fails)


def test_gate_fails_on_demotion_growth_but_notes_shrinkage():
    base = {"admm_coded": _entry(demotions=1)}
    fails, _ = traceaudit.compare_report(
        {"admm_coded": _entry(demotions=2)}, base
    )
    assert any("demotions grew" in f for f in fails)
    base = {"admm_coded": _entry(demotions=2)}
    fails, notes = traceaudit.compare_report(
        {"admm_coded": _entry(demotions=1)}, base
    )
    assert fails == [] and any("shrank" in n for n in notes)


def test_gate_fails_on_grid_missing_from_fresh():
    base = {"admm_coded": _entry(), "walkman": _entry()}
    fresh = {"admm_coded": _entry()}
    fails, _ = traceaudit.compare_report(fresh, base)
    assert any("walkman" in f and "absent" in f for f in fails)


def test_gate_notes_new_grid_without_failing():
    base = {"admm_coded": _entry()}
    fresh = {"admm_coded": _entry(), "walkman": _entry()}
    # walkman's synthetic entry claims pallas on a None-expect grid: fix
    fresh["walkman"]["expect_pallas"] = None
    fails, notes = traceaudit.compare_report(fresh, base)
    assert fails == []
    assert any("walkman" in n and "NEW" in n for n in notes)


def test_baseline_roundtrip(tmp_path):
    path = tmp_path / "audit.json"
    assert traceaudit.load_baseline(path) is None
    traceaudit.write_baseline({"admm_coded": _entry()}, path)
    assert traceaudit.load_baseline(path) == {"admm_coded": _entry()}


# --------------------------------------------------------------------------
# Jaxpr audit: one real lowering vs the committed pin
# --------------------------------------------------------------------------


def test_committed_baseline_matches_live_grids():
    """Every pinned grid still exists in AUDIT_GRIDS (a renamed grid
    without --update-audit would fail the gate in CI)."""
    baseline = json.loads(
        (ROOT / "benchmarks" / "trace_audit.json").read_text()
    )
    live = set(traceaudit._grids())
    assert set(baseline) <= live
    for name, entry in baseline.items():
        assert entry["groups"] == traceaudit._grids()[name].expect_groups


@pytest.mark.parametrize("grid", ["admm_exact", "walkman"])
def test_real_lowering_matches_pin(grid):
    """Lower the two cheapest grids for real (make_jaxpr only — no
    compile) and gate against the committed counts end-to-end."""
    baseline = json.loads(
        (ROOT / "benchmarks" / "trace_audit.json").read_text()
    )
    fresh = traceaudit.audit_report(names=[grid])
    fails, _ = traceaudit.compare_report(
        fresh, {grid: baseline[grid]}
    )
    assert fails == []
    assert fresh[grid]["signatures"] == baseline[grid]["signatures"]
