"""Execution-mesh tests (DESIGN.md §9).

conftest.py forces an 8-CPU-device platform, so these tests exercise the
real sharded tier: the three execution tiers (serial scan, vmapped
batch, mesh-sharded batch) must agree elementwise, sharded must equal
vmapped BITWISE (SPMD partitioning of a runs axis no op crosses cannot
change per-run math), chunked dispatches must equal unchunked, and the
method step must lower through the fused Pallas hot path
`repro.kernels.ops.coded_admm_update`.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.admm import ADMMConfig
from repro.core.graph import make_network
from repro.core.problems import DATASETS, allocate
from repro.experiments import Case, SweepSpec, run_sweep
from repro.methods import driver, get_kernel
from repro.methods.admm import ADMMRun

ITERS = 40
TRACE_FIELDS = (
    "accuracy", "test_error", "z_err", "comm_cost", "sim_time",
    "final_x", "final_z",
)

# conftest.py only setdefaults XLA_FLAGS: a developer running the suite
# with their own XLA_FLAGS legitimately gets a different device count.
# Skip (don't fail) in that case; in CI nothing sets XLA_FLAGS, so this
# module always runs there and test_forced_mesh_present pins that the
# conftest forcing actually took effect.
pytestmark = pytest.mark.skipif(
    len(jax.devices()) != 8,
    reason="suite running without the conftest 8-device forcing "
    "(external XLA_FLAGS set)",
)


def _spec(runs=3, S_values=(0, 1, 2)):
    """9-case fig5-style grid: deliberately NOT divisible by 8 devices,
    so the runs axis exercises the pad-to-device-multiple path."""
    return SweepSpec(
        "sharded_smoke",
        Case(
            method="csI-ADMM", dataset="usps", N=5, K=6, M=36,
            scheme="cyclic", iters=ITERS,
        ),
        axes={"S": list(S_values), "seed": list(range(runs))},
        fixup=lambda c: dataclasses.replace(
            c, scheme="uncoded" if c.S == 0 else c.scheme
        ),
    )


def test_forced_mesh_present():
    """When XLA_FLAGS is the conftest default, 8 devices MUST be visible
    (guards against the forcing silently rotting); the module-level
    skipif already routed externally-overridden runs away."""
    import os

    assert "host_platform_device_count=8" in os.environ.get("XLA_FLAGS", "")
    assert len(jax.devices()) == 8


def test_sharded_equals_vmapped_equals_serial():
    """The acceptance contract: sharded == vmapped bitwise, both == the
    per-run serial reference elementwise."""
    spec = _spec()
    sharded = run_sweep(spec, mode="sharded")
    batched = run_sweep(spec, mode="batched")
    serial = run_sweep(spec, mode="serial")
    assert sharded.mode == "sharded" and sharded.n_devices == 8
    assert batched.mode == "batched"
    assert sharded.cases == batched.cases == serial.cases
    assert sharded.n_dispatches == batched.n_dispatches == 1
    for case, tsh, tb, tse in zip(
        sharded.cases, sharded.traces, batched.traces, serial.traces
    ):
        for field in TRACE_FIELDS:
            np.testing.assert_array_equal(
                getattr(tsh, field), getattr(tb, field),
                err_msg=f"{case} field={field}: sharded != vmapped",
            )
            np.testing.assert_allclose(
                getattr(tsh, field), getattr(tse, field),
                rtol=1e-5, atol=1e-5,
                err_msg=f"{case} field={field}: sharded != serial",
            )


def test_auto_mode_resolves_to_sharded():
    """With 8 visible devices, "auto" (the default) picks the mesh tier."""
    result = run_sweep(_spec(runs=1, S_values=(0,)))
    assert result.mode == "sharded"
    assert result.n_devices == 8


def test_chunked_execution_matches_unchunked(monkeypatch):
    """A 1 MiB budget forces multiple device-aligned chunks; the split
    must be invisible in the outputs."""
    spec = _spec(runs=2)
    whole = run_sweep(spec, mode="sharded")
    monkeypatch.setenv("REPRO_SHARD_MEM_MB", "1")
    chunked = run_sweep(spec, mode="sharded")
    for tw, tc in zip(whole.traces, chunked.traces):
        for field in TRACE_FIELDS:
            np.testing.assert_array_equal(
                getattr(tw, field), getattr(tc, field), err_msg=field
            )


def test_chunk_rule_device_aligned(monkeypatch):
    """Chunk sizes are multiples of D, at least D, at most the padded R."""
    monkeypatch.setenv("REPRO_SHARD_MEM_MB", "1")
    assert driver._chunk_runs(16, 8, per_run_bytes=10 * 2**20) == 8
    monkeypatch.setenv("REPRO_SHARD_MEM_MB", "4096")
    assert driver._chunk_runs(16, 8, per_run_bytes=10 * 2**20) == 16
    assert driver._chunk_runs(24, 4, per_run_bytes=1) == 24


def test_single_device_fallback(monkeypatch):
    """One visible device -> run_sharded degrades structurally to the
    single-device vmap (no mesh, no padding)."""
    spec = _spec(runs=1, S_values=(0, 1))
    batched = run_sweep(spec, mode="batched")
    one = jax.devices()[:1]
    monkeypatch.setattr(driver.jax, "devices", lambda *a: one)
    sharded = run_sweep(spec, mode="sharded")
    for tb, ts in zip(batched.traces, sharded.traces):
        np.testing.assert_array_equal(tb.accuracy, ts.accuracy)


def test_mode_validation():
    spec = _spec(runs=1, S_values=(0,))
    with pytest.raises(ValueError, match="unknown sweep mode"):
        run_sweep(spec, mode="bogus")
    with pytest.raises(ValueError, match="contradicts"):
        run_sweep(spec, serial=True, mode="batched")
    assert run_sweep(spec, serial=True).mode == "serial"
    assert run_sweep(spec, serial=True, mode="serial").mode == "serial"


def test_step_lowers_through_coded_admm_update():
    """Kernel-routing pin: the ADMM family's composed run function must
    contain the fused Pallas decode-combine + x-update (DESIGN.md §5),
    not an unfused decode. I-ADMM (exact_x) keeps its closed-form solve
    and must NOT call it."""
    net = make_network(5, 0.5, seed=0)
    prob = allocate(DATASETS["usps"](0), 5, 3)
    kernel = get_kernel("sI-ADMM")

    def jaxpr_for(cfg):
        run = ADMMRun(cfg)
        prep = kernel.prepare(prob, net, run, 10)
        statics = {**prep.statics, **prep.max_statics}
        fn = driver._compose(kernel, driver._statics_key(statics))
        return str(jax.make_jaxpr(fn)(prep.consts, prep.steps))

    assert "coded_admm_update" in jaxpr_for(ADMMConfig(M=36, K=3))
    assert "coded_admm_update" not in jaxpr_for(
        ADMMConfig(M=36, K=3, exact_x=True)
    )
