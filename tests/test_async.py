"""Event-driven timing mode tests (DESIGN.md §13).

The contracts under test:

- **Bulk-synchronous equivalence**: ``tau_max = 0`` / ``churn_rate = 0``
  cells take the EXACT pre-async code path — bit-identical traces and
  the unchanged synchronous static signature — on every execution tier.
- **Degenerate asynchrony**: a vanishing staleness bound (every delay
  rounds to 0 steps) reproduces the synchronous iterates through the
  ring-buffer path up to compiler reassociation (the async scan is a
  different XLA program, so op fusion may shift last bits; the HARD
  bit-identity guarantee lives at tau_max = 0, which keeps the
  synchronous trace). D-ADMM's dual-first async form is constructed so
  its degenerate limit matches the synchronous sequence too.
- **Staleness bound**: realized landing delays never exceed tau_max in
  simulated time, and never exceed the ring depth in steps.
- **Churn -> alive mask -> decode**: crashed ECNs carry exactly zero
  decode weight, and NaN garbage planted in dead message rows cannot
  leak through the fused combine (the §11 masking guarantee).
- **No retraces**: a whole async grid (many tau_max/churn values) is
  ONE jit trace per static signature (the PR-5/PR-7 schedule-as-data
  pattern).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.admm import ADMMConfig, make_schedule
from repro.core.coding import make_code
from repro.core.graph import make_network
from repro.core.timing import TimingModel
from repro.experiments import Case, get_sweep, run_sweep
from repro.kernels.ops import coded_combine
from repro.methods import driver, get_kernel

ITERS = 30


def _admm_case(**kw) -> Case:
    kw.setdefault("method", "csI-ADMM")
    kw.setdefault("dataset", "synthetic")
    kw.setdefault("K", 6)
    kw.setdefault("M", 360)
    kw.setdefault("S", 1)
    kw.setdefault("scheme", "cyclic")
    kw.setdefault("iters", ITERS)
    kw.setdefault("p_straggle", 0.3)
    kw.setdefault("delay", 5e-3)
    return Case(**kw)


def _gossip_case(method: str, **kw) -> Case:
    kw.setdefault("dataset", "synthetic")
    kw.setdefault("iters", 20)
    kw.setdefault("alpha", 0.05)
    kw.setdefault("rho", 0.1)
    return Case(method=method, **kw)


# --------------------------------------------------------------------------
# Bulk-synchronous equivalence + degenerate asynchrony
# --------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["serial", "batched"])
def test_sync_cell_bit_identical_inside_mixed_sweep(mode):
    """A tau_max=0 cell inside a mixed sync/async grid produces the same
    bits as the standalone synchronous run — the acceptance bar for the
    staleness_frontier control arm."""
    sync = _admm_case()
    mixed = [sync, dataclasses.replace(sync, tau_max=2e-3)]
    ref = run_sweep([sync], mode=mode).traces[0]
    res = run_sweep(mixed, mode=mode)
    assert res.n_dispatches == 2  # sync keeps its own (old) signature
    np.testing.assert_array_equal(res.traces[0].accuracy, ref.accuracy)
    np.testing.assert_array_equal(res.traces[0].final_z, ref.final_z)
    np.testing.assert_array_equal(res.traces[0].sim_time, ref.sim_time)


@pytest.mark.parametrize(
    "case",
    [
        _admm_case(),
        _admm_case(method="cq-sI-ADMM", compressor="quant", bits=8),
        _admm_case(method="pI-ADMM", sigma=0.01),
        _gossip_case("DGD"),
        _gossip_case("EXTRA"),
        _gossip_case("D-ADMM"),
    ],
    ids=["csI-ADMM", "cq-sI-ADMM", "pI-ADMM", "DGD", "EXTRA", "D-ADMM"],
)
def test_degenerate_async_equals_sync(case):
    """tau_max so small every delay rounds to 0 steps: the ring-buffer
    path reproduces the synchronous iterates (write lands in the same
    step it is read; act stays 1 everywhere) to within last-bit
    compiler reassociation of the distinct async program."""
    ref = run_sweep([case], mode="serial").traces[0]
    deg = dataclasses.replace(case, tau_max=1e-12)
    tr = run_sweep([deg], mode="serial").traces[0]
    np.testing.assert_allclose(tr.accuracy, ref.accuracy, rtol=1e-12)
    np.testing.assert_allclose(
        tr.test_error, ref.test_error, rtol=1e-12, atol=1e-15
    )
    np.testing.assert_allclose(tr.final_z, ref.final_z, rtol=1e-12, atol=1e-15)


def test_dadmm_async_runs_and_sync_arm_untouched():
    """D-ADMM under real staleness runs finite and its sync arm inside
    a mixed grid stays bit-exact (it keeps the synchronous trace)."""
    sync = _gossip_case("D-ADMM")
    ref = run_sweep([sync], mode="serial").traces[0]
    res = run_sweep(
        [sync, dataclasses.replace(sync, tau_max=2e-3)], mode="serial"
    )
    np.testing.assert_array_equal(res.traces[0].accuracy, ref.accuracy)
    assert np.isfinite(res.traces[1].accuracy).all()


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs a device mesh")
def test_async_tier_agreement():
    """Serial, batched, and sharded tiers agree elementwise on an async
    grid (same scan, different layout — DESIGN.md §9)."""
    cases = [
        dataclasses.replace(_admm_case(tau_max=2e-3), seed=s)
        for s in range(len(jax.devices()))
    ]
    serial = run_sweep(cases, mode="serial")
    batched = run_sweep(cases, mode="batched")
    sharded = run_sweep(cases, mode="sharded")
    for ts, tb, tsh in zip(serial.traces, batched.traces, sharded.traces):
        np.testing.assert_allclose(tb.accuracy, ts.accuracy, rtol=1e-12)
        np.testing.assert_allclose(tsh.accuracy, ts.accuracy, rtol=1e-12)


# --------------------------------------------------------------------------
# Staleness schedule properties
# --------------------------------------------------------------------------


def test_staleness_steps_zero_bound_is_all_zero():
    tm = TimingModel(tau_max=0.0)
    times = np.cumsum(np.full(50, 1e-3))
    delta = tm.staleness_steps(times, np.random.default_rng(0))
    assert delta.dtype == np.int32
    assert not delta.any()


@pytest.mark.parametrize("n", [0, 7])
def test_staleness_steps_respects_bounds(n):
    """Realized landing delay <= tau_max in sim time AND < staleness_cap
    in steps, for scalar and per-worker shapes."""
    rng = np.random.default_rng(1)
    times = np.cumsum(rng.uniform(1e-4, 3e-3, size=200))
    tm = TimingModel(tau_max=4e-3, staleness_cap=6)
    delta = tm.staleness_steps(times, np.random.default_rng(2), n=n)
    assert delta.shape == ((200, n) if n else (200,))
    assert delta.min() >= 0 and delta.max() < tm.staleness_cap
    k = np.arange(200)
    land = times[np.minimum((k[:, None] if n else k) + delta, 199)]
    emit = times[:, None] if n else times
    assert np.all(land - emit <= tm.tau_max + 1e-15)


def test_sample_churn_properties():
    tm = TimingModel(churn_rate=50.0, mttr=0.0)
    starts = np.cumsum(np.full(300, 1e-3))
    up = tm.sample_churn(starts, 5, np.random.default_rng(3))
    assert up.shape == (300, 5)
    # mttr=0: a crash is permanent — once down, down forever
    for w in range(5):
        col = up[:, w].astype(int)
        assert np.all(np.diff(col) <= 0)
    assert not up.all()  # at this rate someone crashed
    # churn_rate=0: nobody ever crashes
    assert TimingModel().sample_churn(starts, 5, np.random.default_rng(3)).all()
    # recovery: with a short mttr some worker comes back
    up2 = TimingModel(churn_rate=50.0, mttr=5e-3).sample_churn(
        starts, 5, np.random.default_rng(4)
    )
    regained = (np.diff(up2.astype(int), axis=0) > 0).any()
    assert regained


def test_gossip_round_times_alive_mask():
    """Crashed agents drop out of the round max; an all-crashed round
    still advances the clock (floored at base_lo)."""
    net = make_network(6, 0.5, seed=0)
    tm = TimingModel()
    comp, per_agent = tm.gossip_components(net, 10, np.random.default_rng(0))
    nominal = tm.gossip_round_from(comp, per_agent)
    comp2, per2 = tm.gossip_components(net, 10, np.random.default_rng(0))
    np.testing.assert_array_equal(
        nominal, tm.gossip_round_from(comp2, per2, alive=None)
    )
    alive = np.ones((10, 6), dtype=bool)
    alive[3] = False  # everyone down in round 3
    alive[5, :3] = False
    masked = tm.gossip_round_from(comp, per_agent, alive=alive)
    assert masked[3] == tm.base_lo
    assert masked[5] <= nominal[5]
    assert (masked > 0).all()


# --------------------------------------------------------------------------
# Churn -> alive mask -> decode
# --------------------------------------------------------------------------


def _churned_schedule(scheme="mds", churn_rate=40.0, mttr=0.02, iters=400):
    cfg = ADMMConfig(M=360, K=6, S=2, scheme=scheme, seed=0)
    net = make_network(6, 0.5, seed=0)
    code = make_code(scheme, cfg.K, cfg.S, seed=0)
    tm = TimingModel(
        p_straggle=0.3, delay=5e-3, churn_rate=churn_rate, mttr=mttr
    )
    return make_schedule(cfg, net, code, tm, iters, b=720), code


def test_crashed_ecns_never_weighted():
    """Censored ECNs (crashed at iteration start) are outside the alive
    mask and carry exactly zero decode weight; undecodable survivor
    patterns become skipped activations."""
    sched, code = _churned_schedule()
    assert not sched["alive"].all()  # churn actually bit
    assert np.all(sched["decode"][~sched["alive"]] == 0.0)
    dead_iters = sched["act"] == 0.0
    assert np.all(sched["decode"][dead_iters] == 0.0)
    # the clock still advances strictly through dead iterations
    t = np.cumsum(sched["resp_time"] + sched["link_time"])
    assert np.all(np.diff(t) > 0)


def test_undecodable_pattern_skips_activation():
    """A pattern below min_responses cannot decode: cyclic with R=4 of
    K=6 needs >= 4 survivors, so heavy permanent churn must produce
    skipped activations with the epsilon cap as the recorded wait."""
    sched, code = _churned_schedule(scheme="cyclic", churn_rate=80.0, mttr=0.0)
    n_resp = sched["alive"].sum(axis=1)
    undecodable = n_resp < code.min_responses
    assert undecodable.any()
    assert np.all(sched["act"][undecodable] == 0.0)


def test_nan_in_dead_rows_cannot_leak():
    """NaN planted in masked-out message rows never reaches the decoded
    combine — the §11 guarantee churn relies on."""
    rng = np.random.default_rng(0)
    msgs = rng.normal(size=(6, 64)).astype(np.float32)
    coeffs = rng.normal(size=6).astype(np.float32)
    mask = np.array([1, 1, 0, 1, 0, 1], dtype=np.float32)
    poisoned = msgs.copy()
    poisoned[mask == 0] = np.nan
    clean = coded_combine(msgs, coeffs, mask)
    out = coded_combine(poisoned, coeffs, mask)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_array_equal(np.asarray(out), np.asarray(clean))


def test_churned_run_stays_finite_and_degrades():
    """End-to-end: heavy churn leaves iterates finite, and the decodable
    -pattern gap shows up — MDS (any-R decode) beats cyclic under the
    same crash schedule."""
    base = _admm_case(S=2, churn_rate=25.0, mttr=0.05, iters=200)
    res = run_sweep(
        [base, dataclasses.replace(base, scheme="mds")], mode="batched"
    )
    cyc, mds = res.traces
    assert np.isfinite(cyc.accuracy).all() and np.isfinite(mds.accuracy).all()
    assert mds.accuracy[-1] <= cyc.accuracy[-1] + 1e-9


# --------------------------------------------------------------------------
# No retraces; composition with streaming reductions
# --------------------------------------------------------------------------


def test_async_schedules_cause_no_retrace():
    """Every tau_max/churn value of an async grid shares ONE jit trace:
    the schedules are scan data, not statics (PR-5/PR-7 pattern)."""
    driver._batch_fn.cache_clear()
    cases = [
        _admm_case(tau_max=t, churn_rate=c, mttr=0.05, iters=ITERS)
        for t, c in [(5e-4, 0.0), (2e-3, 0.0), (8e-3, 10.0), (0.0, 25.0)]
    ]
    res = run_sweep(cases, mode="batched")
    assert res.n_dispatches == 1
    assert driver._batch_fn.cache_info().currsize == 1


def test_async_composes_with_streaming_reductions():
    """Event-driven runs flow through the in-scan Reduction fold (§12):
    O(grid) summaries, no materialized traces."""
    from repro.methods import Reduction

    spec = dataclasses.replace(
        get_sweep("churn_grid", iters=24, runs=1),
        reductions=Reduction(
            fields=("accuracy",), budgets=(0.5, 1.0), x="sim_time"
        ),
    )
    res = run_sweep(spec, mode="batched")
    assert res.traces == [] and res.reduced is not None
    for v in res.reduced.values():
        assert np.isfinite(v).all()


def test_walkman_rejects_async():
    """W-ADMM has no event-driven mode: loud failure, not silent sync."""
    case = Case(method="W-ADMM", dataset="synthetic", iters=10, tau_max=1e-3)
    with pytest.raises(NotImplementedError, match="event-driven"):
        run_sweep([case], mode="serial")


def test_timing_model_validation():
    with pytest.raises(ValueError, match="tau_max"):
        TimingModel(tau_max=-1.0)
    with pytest.raises(ValueError, match="staleness_cap"):
        TimingModel(staleness_cap=1)
    assert not TimingModel().is_async
    assert TimingModel(tau_max=1e-3).is_async
    assert TimingModel(churn_rate=1.0).is_async
