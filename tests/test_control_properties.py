"""Controller-theory properties of the bandit layer (DESIGN.md §15).

Three families:

- **Regret**: on synthetic stationary reward tables with a hidden best
  arm, UCB1/EXP3 cumulative reward approaches the best arm's and the
  per-step regret slope decreases across doubling horizons (T, 2T, 4T).
  Threshold slack is calibrated (0 violations over 3000 random configs):
  UCB1 is near-deterministic after its round-robin init; EXP3 keeps a
  persistent gamma-exploration floor whose binomial noise at these
  horizons is ~0.01 per-step regret. The deterministic corpus always
  runs; when `hypothesis` (optional dev dependency) is present the same
  check is additionally driven over drawn seeds/arm counts.
- **Degenerate bit-identity**: a single-arm controller IS the static
  csI-ADMM path — identical statics, steps, consts, jaxpr (same XLA
  program) and bitwise-identical executed traces.
- **Permutation equivariance in arm order**: the controller state
  transforms covariantly — `update` for both algorithms, UCB1's
  post-init argmax selection, and EXP3's arm distribution.

The execution-tier/composition contracts live in ``test_control.py``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.control import (
    BANDIT_ALGOS,
    BanditPolicy,
    replay,
    schedule_inputs,
    select,
    update,
)
from repro.control.bandit import _exp3_probs
from repro.core.graph import make_network
from repro.core.problems import DATASETS, allocate
from repro.experiments import Case, run_sweep
from repro.methods import driver, get_kernel

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency (requirements-dev.txt)
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------------
# Regret on synthetic stationary reward streams
# --------------------------------------------------------------------------

HORIZON = 192  # evaluated at T, 2T, 4T


def _reward_table(seed: int, n_arms: int, iters: int):
    """Stationary table with a hidden best arm (gap >= 0.05 by
    construction: one mean is lifted 0.5 above a [0.05, 0.45] draw)."""
    rng = np.random.default_rng(seed)
    means = rng.uniform(0.05, 0.45, n_arms)
    best = rng.integers(n_arms)
    means[best] += 0.5
    rewards = np.clip(means + rng.normal(0, 0.05, (iters, n_arms)), 0, 1)
    return rewards, means


def _check_regret(algo: str, seed: int, n_arms: int) -> None:
    iters = 4 * HORIZON
    rewards, means = _reward_table(seed, n_arms, iters)
    u, logk = schedule_inputs(iters, seed)
    pulls = replay(BanditPolicy(algo=algo), rewards, u, logk)
    best = int(np.argmax(means))
    gaps = means[best] - means
    regret = np.cumsum(gaps[pulls])  # pseudo-regret vs always-best oracle
    avg = [regret[T - 1] / T for T in (HORIZON, 2 * HORIZON, 4 * HORIZON)]
    share = np.mean(pulls[2 * HORIZON:] == best)
    if algo == "ucb1":
        # Deterministic index: tight slack, strong overall decrease.
        assert avg[1] <= avg[0] + 2e-3
        assert avg[2] <= avg[1] + 2e-3
        assert avg[2] <= 0.6 * avg[0] + 1e-9
        assert share > 0.8
    else:
        # EXP3 keeps exploring at rate gamma: slack covers the binomial
        # noise of the exploration floor at these horizons.
        assert avg[1] <= avg[0] + 0.012
        assert avg[2] <= avg[1] + 0.012
        assert share > 0.6
    # Cumulative reward approaches the best arm's.
    assert np.mean(means[pulls]) >= means[best] - 0.15


@pytest.mark.parametrize("algo", BANDIT_ALGOS)
@pytest.mark.parametrize("seed", range(4))
def test_regret_decreases_across_doubling_horizons(algo, seed):
    _check_regret(algo, seed, n_arms=2 + seed % 5)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        algo=st.sampled_from(BANDIT_ALGOS),
        seed=st.integers(0, 1499),
        n_arms=st.integers(2, 6),
    )
    def test_regret_hypothesis(algo, seed, n_arms):
        _check_regret(algo, seed, n_arms)


# --------------------------------------------------------------------------
# Single-arm degenerate: bit-identical to the static PR-5 path
# --------------------------------------------------------------------------

TRACE_FIELDS = (
    "accuracy", "test_error", "z_err", "sim_time", "final_x", "final_z",
)


def _frontier_case(**kw) -> Case:
    kw.setdefault("dataset", "synthetic")
    kw.setdefault("K", 6)
    kw.setdefault("M", 360)
    kw.setdefault("iters", 25)
    kw.setdefault("p_straggle", 0.3)
    kw.setdefault("delay", 5e-3)
    return Case(**kw)


def test_single_arm_controller_bit_identical_to_static():
    """len(arms)==1 degenerates to csI-ADMM exactly: same statics, same
    step arrays, same jaxpr — therefore the same XLA program — and the
    executed trace matches bit for bit."""
    arm = ("approx", 1, 3e-4)
    case_a = _frontier_case(method="a-csI-ADMM", arms=(arm,))
    case_s = _frontier_case(
        method="csI-ADMM", scheme=arm[0], S=arm[1], deadline=arm[2]
    )
    net = make_network(case_a.N, case_a.connectivity, seed=case_a.seed)
    prob = allocate(DATASETS[case_a.dataset](case_a.seed), case_a.N, case_a.K)
    ka, ks = get_kernel("a-csI-ADMM"), get_kernel("csI-ADMM")
    pa = ka.prepare(prob, net, ka.config(case_a), case_a.iters)
    ps = ks.prepare(prob, net, ks.config(case_s), case_s.iters)
    assert pa.statics == ps.statics
    assert pa.max_statics == ps.max_statics
    for a, s in zip(pa.steps, ps.steps):
        np.testing.assert_array_equal(a, s)
    for a, s in zip(pa.consts, ps.consts):
        np.testing.assert_array_equal(a, s)
    key = driver._statics_key({**pa.statics, **pa.max_statics})
    ja = jax.make_jaxpr(driver._compose(ka, key))(pa.consts, pa.steps)
    js = jax.make_jaxpr(driver._compose(ks, key))(ps.consts, ps.steps)
    assert str(ja) == str(js)
    ta = run_sweep([case_a], mode="serial").traces[0]
    ts = run_sweep([case_s], mode="serial").traces[0]
    for f in TRACE_FIELDS:
        np.testing.assert_array_equal(getattr(ta, f), getattr(ts, f))


def test_single_arm_still_gets_its_own_dispatch_group():
    """The ("adaptive", 1, algo) signature suffix keeps the degenerate
    case out of static groups (another kernel would config-build the
    group's first case), at zero cost: the jaxpr is the static one."""
    arm = ("cyclic", 1, None)
    cases = [
        _frontier_case(method="a-csI-ADMM", arms=(arm,)),
        _frontier_case(method="csI-ADMM", scheme=arm[0], S=arm[1]),
    ]
    res = run_sweep(cases, mode="batched")
    assert res.n_dispatches == 2
    for f in TRACE_FIELDS:
        np.testing.assert_array_equal(
            getattr(res.traces[0], f), getattr(res.traces[1], f)
        )


# --------------------------------------------------------------------------
# Permutation equivariance in arm order
# --------------------------------------------------------------------------


@pytest.mark.parametrize("algo", BANDIT_ALGOS)
def test_update_is_permutation_equivariant(algo):
    """Relabeling the arms relabels the state: update(sigma(state),
    sigma(arm)) == sigma(update(state, arm)) for every arm."""
    rng = np.random.default_rng(0)
    n_arms = 5
    par = BanditPolicy(algo=algo).params
    state = dict(
        n=jnp.asarray(rng.integers(1, 9, n_arms).astype(float)),
        s=jnp.asarray(rng.normal(size=n_arms)),
    )
    perm = rng.permutation(n_arms)
    inv = np.argsort(perm)
    pstate = {k: v[perm] for k, v in state.items()}
    for arm in range(n_arms):
        out = update(algo, state, arm, 0.7, par, n_arms)
        pout = update(algo, pstate, int(inv[arm]), 0.7, par, n_arms)
        for k in ("n", "s"):
            np.testing.assert_allclose(
                np.asarray(pout[k]), np.asarray(out[k])[perm], rtol=1e-12
            )


def test_ucb1_select_is_permutation_equivariant_after_init():
    """Past the round-robin init (all n >= 1), the UCB1 pull follows the
    arm relabeling: the selected physical arm is permutation-invariant."""
    rng = np.random.default_rng(1)
    n_arms = 6
    par = BanditPolicy().params
    n = rng.integers(1, 20, n_arms).astype(float)
    state = dict(n=n, s=rng.uniform(0, 1, n_arms) * n)
    u, logk = 0.3, np.log(50.0)
    arm = int(select("ucb1", state, u, logk, par, n_arms))
    for trial in range(5):
        perm = np.random.default_rng(trial).permutation(n_arms)
        pstate = {k: v[perm] for k, v in state.items()}
        parm = int(select("ucb1", pstate, u, logk, par, n_arms))
        assert perm[parm] == arm


def test_exp3_distribution_is_permutation_equivariant():
    """EXP3's arm distribution commutes with arm relabeling (the CDF
    inversion then samples the same physical arm in distribution)."""
    rng = np.random.default_rng(2)
    n_arms = 6
    par = BanditPolicy(algo="exp3").params
    s = rng.normal(size=n_arms)
    p = np.asarray(_exp3_probs(s, par, n_arms))
    np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-12)
    for trial in range(5):
        perm = np.random.default_rng(trial).permutation(n_arms)
        np.testing.assert_allclose(
            np.asarray(_exp3_probs(s[perm], par, n_arms)), p[perm],
            rtol=1e-12,
        )


def test_replay_matches_manual_recursion_on_tiny_table():
    """Spot-check the host twin against a hand-unrolled UCB1 recursion
    on a 2-arm, 4-step table (round-robin, then the better arm)."""
    rewards = np.array([[0.9, 0.1], [0.9, 0.1], [0.9, 0.1], [0.9, 0.1]])
    u = np.zeros(4)
    logk = np.log(np.arange(1, 5, dtype=float))
    pulls = replay(BanditPolicy(algo="ucb1", c=0.5), rewards, u, logk)
    assert list(pulls) == [0, 1, 0, 0]
