"""Dry-run integration smoke: lowering + the cost pipeline end-to-end in a
subprocess (the 512-device flag must be set before jax init, so it cannot
run in the main pytest process). One small arch both meshes + consensus."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=560):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, cwd=ROOT, env=env, timeout=timeout,
    )


@pytest.mark.slow
def test_dryrun_single_and_multi(tmp_path):
    out = tmp_path / "rec.jsonl"
    r = _run(["--arch", "qwen3-0.6b", "--shape", "train_4k", "--out", str(out)])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    recs = [json.loads(l) for l in out.read_text().splitlines()]
    assert len(recs) == 2  # single + multi pod
    meshes = {rec["mesh"] for rec in recs}
    assert meshes == {"16x16", "2x16x16"}
    for rec in recs:
        assert rec["flops_dev"] > 1e12  # trip-count-aware (XLA's is ~30x less)
        assert rec["flops_dev"] > 3 * rec["xla_flops_dev"]
        assert rec["collective_bytes_dev"] > 0
        assert rec["bottleneck"] in ("compute_s", "memory_s", "collective_s")
        assert rec["unknown_trip_whiles"] == 0


@pytest.mark.slow
def test_dryrun_consensus_train():
    r = _run([
        "--arch", "qwen3-0.6b", "--shape", "train_4k", "--mesh", "multi",
        "--consensus",
    ])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("{")][0]
    rec = json.loads(line)
    assert rec["step"].startswith("consensus_train")
    assert rec["mesh"] == "2x16x16"
    assert rec["flops_dev"] > 0 and rec["collective_bytes_dev"] > 0


@pytest.mark.slow
def test_dryrun_decode_skip_rules():
    # long_500k on a pure full-attention arch must be skipped with a reason
    r = _run(["--arch", "llama3-405b", "--shape", "long_500k", "--mesh", "single"])
    assert r.returncode == 0
    assert "0 lowered" in r.stdout or "skipped" in r.stdout
