"""Validation of the trip-count-aware HLO cost analyzer against programs
with analytically known FLOP counts (the thing XLA's cost_analysis gets
wrong for lax.scan bodies)."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze_hlo


def _cost(fn, *shapes):
    lowered = jax.jit(fn).lower(
        *[jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    )
    return analyze_hlo(lowered.compile().as_text())


def test_single_matmul():
    c = _cost(lambda a, b: a @ b, (512, 512), (512, 512))
    expect = 2 * 512**3
    assert abs(c.flops - expect) / expect < 0.02
    # bytes: 3 x 1MB minimum
    assert c.bytes >= 3 * 512 * 512 * 4


def test_scan_multiplies_body():
    L = 8

    def f(x, ws):
        def body(x, w):
            return x @ w, None

        x, _ = jax.lax.scan(body, x, ws)
        return x

    c = _cost(f, (256, 256), (L, 256, 256))
    expect = L * 2 * 256**3
    assert abs(c.flops - expect) / expect < 0.05, c.flops
    # the dynamic-slice of the weight + the matmul operands run L times
    assert c.bytes > L * 3 * 256 * 256 * 4
    assert c.unknown_trip_whiles == 0


def test_scan_matches_unrolled():
    L = 6

    def scan_f(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None

        x, _ = jax.lax.scan(body, x, ws)
        return x

    def unrolled_f(x, ws):
        for i in range(L):
            x = jnp.tanh(x @ ws[i])
        return x

    cs = _cost(scan_f, (128, 128), (L, 128, 128))
    cu = _cost(unrolled_f, (128, 128), (L, 128, 128))
    assert abs(cs.flops - cu.flops) / cu.flops < 0.05


def test_nested_scan():
    Lo, Li = 4, 5

    def f(x, ws):
        def outer(x, w):
            def inner(x, _):
                return x @ w, None

            x, _ = jax.lax.scan(inner, x, None, length=Li)
            return x, None

        x, _ = jax.lax.scan(outer, x, ws)
        return x

    c = _cost(f, (128, 128), (Lo, 128, 128))
    expect = Lo * Li * 2 * 128**3
    assert abs(c.flops - expect) / expect < 0.05, c.flops


def test_grad_counts_backward():
    def f(x, w):
        return jnp.sum(jnp.tanh(x @ w))

    def step(x, w):
        return jax.grad(f, argnums=1)(x, w)

    c_fwd = _cost(f, (256, 256), (256, 256))
    c_grad = _cost(step, (256, 256), (256, 256))
    # grad includes fwd matmul + 1 bwd matmul (dW = x^T delta) >= 2x fwd dot
    assert c_grad.flops > 1.8 * c_fwd.flops


def test_collectives_inside_scan_multiplied():
    import os
    import subprocess
    import sys

    # collectives need >1 device: run in a subprocess with 4 host devices
    prog = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import sys
sys.path.insert(0, "src")
from repro.launch.hlo_cost import analyze_hlo

L = 7
mesh = jax.make_mesh((4,), ("d",))
def f(x, ws):
    def body(x, w):
        return jax.lax.with_sharding_constraint(x @ w, NamedSharding(mesh, P())), None
    x, _ = jax.lax.scan(body, x, ws)
    return x
with mesh:
    lowered = jax.jit(
        f,
        in_shardings=(NamedSharding(mesh, P("d", None)), NamedSharding(mesh, P())),
        out_shardings=NamedSharding(mesh, P()),
    ).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((L, 64, 64), jnp.float32),
    )
    c = analyze_hlo(lowered.compile().as_text())
counts = {k: v for k, v in c.collective_counts.items() if v}
total = sum(counts.values())
assert total >= L, (counts, total)
print("OK", counts)
"""
    r = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
