"""Sweep-engine tests: vmapped grids must match per-run serial execution.

The acceptance contract of the engine (DESIGN.md §7): executing a grid as
batched vmapped scans is a pure performance transform — same seeds in,
same traces out, elementwise. The fig5-style grid below is the paper's
K x S x seed shape at smoke scale.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.admm import ADMMConfig, run_incremental_admm
from repro.core.graph import make_network
from repro.core.problems import DATASETS, allocate
from repro.experiments import (
    Case,
    SweepSpec,
    get_sweep,
    mean_ci,
    reduce_mean,
    run_sweep,
    stack_field,
)
from repro.experiments.sweep import _signature

ITERS = 60


def _fig5_style_spec(runs=2, S_values=(0, 1, 2)):
    """K=6 grid like fig5, shrunk (usps standin, M=36) for test time."""
    return SweepSpec(
        "fig5_smoke",
        Case(
            method="csI-ADMM", dataset="usps", N=5, K=6, M=36,
            scheme="cyclic", iters=ITERS,
        ),
        axes={"S": list(S_values), "seed": list(range(runs))},
        fixup=lambda c: dataclasses.replace(
            c, scheme="uncoded" if c.S == 0 else c.scheme
        ),
    )


def test_grid_expansion_and_dedupe():
    spec = _fig5_style_spec(runs=3)
    cases = spec.cases()
    assert len(cases) == 9
    assert {c.S for c in cases} == {0, 1, 2}
    assert all(c.scheme == ("uncoded" if c.S == 0 else "cyclic") for c in cases)
    # dict-valued axes + fixup dedupe: two axis points collapsing to the
    # same case appear once
    spec2 = SweepSpec(
        "dedupe",
        Case(),
        axes={"scheme": [{"S": 0, "scheme": "uncoded"},
                         {"S": 0, "scheme": "cyclic"}]},
        fixup=lambda c: dataclasses.replace(c, scheme="uncoded"),
    )
    assert len(spec2.cases()) == 1


def test_vmapped_matches_serial_elementwise():
    """Same seeds -> same traces, vmapped vs the per-run seed entry point."""
    spec = _fig5_style_spec(runs=2)
    batched = run_sweep(spec)
    serial = run_sweep(spec, serial=True)
    assert batched.cases == serial.cases
    for case, tb, ts in zip(batched.cases, batched.traces, serial.traces):
        for field in ("accuracy", "test_error", "z_err", "comm_cost",
                      "sim_time", "final_x", "final_z"):
            np.testing.assert_allclose(
                getattr(tb, field), getattr(ts, field),
                rtol=1e-5, atol=1e-5, err_msg=f"{case} field={field}",
            )


def test_vmapped_matches_direct_seed_api():
    """Engine output == calling run_incremental_admm by hand (the seed
    implementation the figure scripts used before the engine existed)."""
    spec = _fig5_style_spec(runs=2, S_values=(0, 1))
    result = run_sweep(spec)
    for case, tr in zip(result.cases, result.traces):
        net = make_network(case.N, case.connectivity, seed=case.seed)
        prob = allocate(DATASETS[case.dataset](case.seed), case.N, case.K)
        ref = run_incremental_admm(
            prob, net, case.admm_config(), case.iters,
            straggler=case.timing_model(),
        )
        np.testing.assert_allclose(
            tr.accuracy, ref.accuracy, rtol=1e-5, atol=1e-5,
            err_msg=str(case),
        )


def test_single_dispatch_per_static_group():
    """The whole S x seed grid costs ONE batched dispatch: the sub-batch
    size mu = M/((S+1)K) is a runtime input of the masked batched scan,
    so different S values share a static signature (and one jit trace)."""
    spec = _fig5_style_spec(runs=3)
    result = run_sweep(spec)
    assert len(result.cases) == 9
    assert result.n_dispatches == 1
    assert [n for _, n in result.groups] == [9]
    sigs = {_signature(c, allocate(DATASETS[c.dataset](c.seed), c.N, c.K))
            for c in result.cases}
    assert len(sigs) == 1


def test_baseline_methods_batch_and_match():
    cases = [
        Case(method=m, dataset="usps", N=5, K=3, M=33, iters=40, seed=s)
        for m in ("W-ADMM", "D-ADMM", "DGD", "EXTRA")
        for s in (0, 1)
    ]
    batched = run_sweep(cases)
    serial = run_sweep(cases, serial=True)
    assert batched.n_dispatches == 4  # one vmapped dispatch per method
    for case, tb, ts in zip(cases, batched.traces, serial.traces):
        np.testing.assert_allclose(
            tb.accuracy, ts.accuracy, rtol=1e-5, atol=1e-5,
            err_msg=str(case),
        )
        np.testing.assert_allclose(tb.comm_cost, ts.comm_cost)


def test_mean_reduction_matches_numpy():
    spec = _fig5_style_spec(runs=3)
    result = run_sweep(spec)
    red = reduce_mean(result, by=("S",))
    assert set(red) == {(0,), (1,), (2,)}
    for (S,), r in red.items():
        runs = stack_field(
            [t for c, t in zip(result.cases, result.traces) if c.S == S],
            "accuracy",
        )
        assert r["n"] == 3
        np.testing.assert_allclose(r["mean"], runs.mean(axis=0))
        # CI: 1.96 * sample std / sqrt(n)
        np.testing.assert_allclose(
            r["ci"], 1.96 * runs.std(axis=0, ddof=1) / np.sqrt(3)
        )
    # n=1 groups get zero-width CI
    m, ci = mean_ci(np.ones((1, 5)))
    np.testing.assert_allclose(ci, 0.0)


def test_mixed_statics_rejected_by_batch_driver():
    from repro.methods import get_kernel, run_batch
    from repro.methods.admm import ADMMRun

    kernel = get_kernel("sI-ADMM")
    nets = [make_network(5, 0.5, seed=s) for s in (0, 1)]
    probs = [allocate(DATASETS["usps"](s), 5, k) for s, k in ((0, 3), (1, 6))]
    cfgs = [
        ADMMRun(ADMMConfig(M=12, K=3, seed=0)),
        ADMMRun(ADMMConfig(M=12, K=6, seed=1)),
    ]
    with pytest.raises(ValueError, match="static signatures"):
        run_batch(kernel, probs, nets, cfgs, 10)

    # ...but mixed mini-batch sizes M (hence mixed mu) batch fine: mu is a
    # runtime input of the masked kernel step, not a jit static.
    probs = [allocate(DATASETS["usps"](s), 5, 3) for s in (0, 1)]
    cfgs = [
        ADMMRun(ADMMConfig(M=12, K=3, seed=0)),
        ADMMRun(ADMMConfig(M=24, K=3, seed=1)),
    ]
    traces = run_batch(kernel, probs, nets, cfgs, 20)
    for prob, net, run, tr in zip(probs, nets, cfgs, traces):
        ref = run_incremental_admm(prob, net, run.cfg, 20)
        np.testing.assert_allclose(
            tr.accuracy, ref.accuracy, rtol=1e-5, atol=1e-5
        )


def test_registry_sweeps_resolve():
    from repro.experiments import SWEEPS

    for name in SWEEPS:
        spec = get_sweep(name, iters=8, runs=1)
        cases = spec.cases()
        assert cases, name
        for c in cases:
            if c.method in (
                "sI-ADMM", "csI-ADMM", "I-ADMM", "pI-ADMM", "cq-sI-ADMM"
            ):
                c.admm_config().validate()

    with pytest.raises(KeyError):
        get_sweep("nonexistent")


# Pinned grid shape of every named sweep at (iters=8, runs=1):
# (n_cases, n_static_groups). Registry edits that change how many traces a
# sweep compiles or how many runs it dispatches must update this table —
# trace counts can't silently explode.
EXPECTED_GRIDS = {
    "fig3_minibatch": (4, 1),  # M is runtime (masked mu): one trace
    "fig3_baselines": (5, 5),  # one method = one kernel = one trace
    "fig3_stragglers": (9, 2),  # K=4 fractional splits off (b, K differ)
    "fig3e_runtime": (5, 5),  # one method = one kernel = one trace
    "fig4_baselines": (5, 5),
    "fig4_stragglers": (2, 1),  # S/scheme are runtime: one trace
    "fig5": (4, 1),  # the tentpole: whole S sweep shares one trace
    "topology_grid": (15, 1),  # S=0 scheme points merge; eta is runtime
    "code_frontier": (10, 1),  # deadline merges for exact families
    "adaptive_frontier": (2, 2),  # arms are runtime; one group per algo

    "privacy_grid": (8, 1),  # sigma and S are runtime: one trace
    "compression_grid": (9, 3),  # one trace per compressor static
    "hetero_grid": (15, 1),  # speed classes are host-side clock only
    "mesh_scale": (3, 1),  # S=0 schemes merge; S/scheme are runtime
    "fleet_frontier": (12, 1),  # response/scheme/deadline/S all runtime
    # per method: one sync group (tau_max=0) + one async ring group
    "staleness_frontier": (16, 8),
    "churn_grid": (9, 2),  # churn_rate=0 keeps the sync signature
}


def test_registry_sweep_counts():
    """Smoke-materialize every named sweep; pin case and group counts."""
    from repro.experiments import SWEEPS
    from repro.experiments.sweep import _materialize

    assert set(EXPECTED_GRIDS) == set(SWEEPS)
    for name, (n_cases, n_groups) in EXPECTED_GRIDS.items():
        spec = get_sweep(name, iters=8, runs=1)
        cases = spec.cases()
        net_cache, prob_cache = {}, {}
        sigs = {
            _signature(c, _materialize(c, net_cache, prob_cache)[1])
            for c in cases
        }
        assert len(cases) == n_cases, f"{name}: {len(cases)} cases"
        assert len(sigs) == n_groups, f"{name}: {len(sigs)} static groups"


def test_mesh_scale_default_grid_is_48():
    """The 2 (S) x 2 (scheme) x 16 (seed) axis product is 64 points, but
    the S=0 cyclic/fractional points dedupe to one uncoded case per seed:
    48 runs — what the docstring promises and the mesh actually sees."""
    assert len(get_sweep("mesh_scale").cases()) == 48
