"""docs-check tool tests: the sweep-coverage gate (ISSUE 8 satellite).

`tools/docs_check.py` works from source text on purpose (no jax import
in a CI lint step) — citations by regex, the SWEEPS registry by
``ast.parse`` (ISSUE 9 replaced the line-regex that silently dropped
any entry with a trailing comment or wrapped onto two lines). These
tests pin both halves — citation resolution and the registered-sweep/
EXPERIMENTS.md coverage contract — including the failure modes:
registering a sweep without documenting it must fail, and a registry
parsing to zero sweeps is itself an error.
"""

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import docs_check  # noqa: E402


def test_registered_sweeps_parse_matches_registry():
    """The source-level parse agrees with the live SWEEPS registry."""
    from repro.experiments import SWEEPS

    names = docs_check.registered_sweeps(
        (ROOT / docs_check.REGISTRY).read_text()
    )
    assert set(names) == set(SWEEPS)


def test_shipped_tree_passes():
    cite_errors, n_refs = docs_check.citation_errors()
    sweep_errors, n_sweeps = docs_check.sweep_coverage_errors()
    assert cite_errors == [] and sweep_errors == []
    assert n_refs > 0 and n_sweeps >= 16


def test_undocumented_sweep_fails(tmp_path):
    """Register a sweep the docs never mention -> docs-check error."""
    root = tmp_path
    (root / "src/repro/experiments").mkdir(parents=True)
    (root / "src/repro/experiments/registry.py").write_text(
        "SWEEPS: Dict[str, Callable[..., SweepSpec]] = {\n"
        '    "fig5": fig5,\n'
        '    "ghost_sweep": ghost_sweep,\n'
        "}\n"
    )
    (root / "EXPERIMENTS.md").write_text(
        "# Experiments\n\nThe fig5 sweep reproduces Fig. 5.\n"
    )
    errors, n = docs_check.sweep_coverage_errors(root)
    assert n == 2
    assert len(errors) == 1 and "ghost_sweep" in errors[0]


def test_word_boundary_not_substring(tmp_path):
    """'churn_grid_v2' in the doc must NOT satisfy 'churn_grid'... but a
    name inside a table cell or backticks does count."""
    root = tmp_path
    (root / "src/repro/experiments").mkdir(parents=True)
    (root / "src/repro/experiments/registry.py").write_text(
        'SWEEPS = {\n    "churn_grid": churn_grid,\n}\n'
    )
    (root / "EXPERIMENTS.md").write_text("only `churn_grid_v2` here\n")
    errors, _ = docs_check.sweep_coverage_errors(root)
    assert len(errors) == 1
    (root / "EXPERIMENTS.md").write_text("| `churn_grid` | table row |\n")
    errors, _ = docs_check.sweep_coverage_errors(root)
    assert errors == []


def test_trailing_comment_and_wrapped_entries_parse(tmp_path):
    """The exact shapes the old line-regex dropped: a trailing comment
    after the factory, an entry wrapped across lines, and a key whose
    factory is a call rather than a bare name. All must be checked —
    and all must FAIL coverage when the doc never mentions them."""
    root = tmp_path
    (root / "src/repro/experiments").mkdir(parents=True)
    (root / "src/repro/experiments/registry.py").write_text(
        "SWEEPS: Dict[str, Callable[..., SweepSpec]] = {\n"
        '    "commented": commented,  # gated via BENCH_SWEEPS\n'
        '    "wrapped":\n'
        "        make_wrapped_factory(iters=1200),\n"
        '    "plain": plain,\n'
        "}\n"
    )
    (root / "EXPERIMENTS.md").write_text("only `plain` documented\n")
    errors, n = docs_check.sweep_coverage_errors(root)
    assert n == 3
    assert sorted(e.split("'")[1] for e in errors) == [
        "commented",
        "wrapped",
    ]


def test_empty_registry_is_an_error(tmp_path):
    root = tmp_path
    (root / "src/repro/experiments").mkdir(parents=True)
    (root / "src/repro/experiments/registry.py").write_text("SWEEPS = {\n}\n")
    (root / "EXPERIMENTS.md").write_text("# Experiments\n")
    errors, n = docs_check.sweep_coverage_errors(root)
    assert n == 0 and len(errors) == 1
