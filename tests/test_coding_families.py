"""Code-family subsystem certification suite (DESIGN.md §11).

Every registered `CodeFamily` is swept over a (K, S) grid and held to the
contract the decode path relies on:

- any alive set of exactly R = K - S responses decodes the exact
  partition-gradient sum (or lands within the certified ``err_bound``
  for the partial-recovery family);
- decode vectors satisfy a^T B ~= 1^T (the all-ones target lies in the
  rowspan of the alive rows) and are supported on alive ECNs only;
- replication/storage accounting matches ``support()`` row by row;
- `make_code` rejects infeasible (K, S) with a clear, uniform
  ValueError *before* any construction math can fail cryptically
  (satellite regression tests pin the messages).

Deterministic tests run everywhere; the Hypothesis property section
(mirroring ``tests/test_coding_properties.py``) is defined only when
``hypothesis`` is installed (optional dev dependency, present in CI).
"""

import itertools

import numpy as np
import pytest

from repro.core.coding import (
    CODE_FAMILIES,
    CodeFamily,
    GradientCode,
    make_code,
    register_family,
)

try:
    from hypothesis import assume, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency; CI installs it
    HAVE_HYPOTHESIS = False

# The certification grid: every (family, K, S) that is feasible is built
# and certified; infeasible combos must raise the family's clear error.
KS_GRID = [(3, 1), (4, 1), (4, 2), (6, 1), (6, 2), (8, 3), (9, 2)]
FAMILIES = sorted(CODE_FAMILIES)


def _feasible(name: str, K: int, S: int) -> bool:
    if name == "uncoded":
        return S == 0
    try:
        CODE_FAMILIES[name].check(K, S)
    except ValueError:
        return False
    return True


def _grid(name: str):
    ks = [(K, 0) for K, _ in KS_GRID] if name == "uncoded" else KS_GRID
    return [(K, S) for K, S in dict.fromkeys(ks) if _feasible(name, K, S)]


def _alive_patterns(K: int, n_alive: int):
    for alive_idx in itertools.combinations(range(K), n_alive):
        alive = np.zeros(K, dtype=bool)
        alive[list(alive_idx)] = True
        yield alive


def _check_decode_contract(code: GradientCode, alive: np.ndarray, rng):
    """One alive pattern: decode identity, support, and error bound."""
    g = rng.standard_normal((code.K, 5))
    a = code.decode_vector(alive)
    # decode vector supported on alive ECNs only
    assert np.all(np.abs(a[~alive]) < 1e-12)
    resid = a @ code.B - np.ones(code.K)
    got = code.decode(code.encode(g), alive)
    err = np.abs(got - g.sum(0)).max()
    if code.exact:
        # a^T B == 1^T exactly: 1 lies in rowspan(B[alive])
        np.testing.assert_allclose(resid, 0, atol=1e-7)
        assert err < 1e-7
    else:
        # within the certified bound, per coordinate (Cauchy-Schwarz)
        bound = np.linalg.norm(resid)
        assert bound <= code.err_bound * (1 + 1e-6) + 1e-9
        col_norms = np.linalg.norm(g, axis=0)
        assert (np.abs(got - g.sum(0)) <= bound * col_norms + 1e-9).all()


@pytest.mark.parametrize("name", FAMILIES)
def test_family_certifies_across_grid(name):
    """verify() passes for every feasible (K, S) of every family."""
    grid = _grid(name)
    assert grid, f"{name}: empty feasible grid"
    for K, S in grid:
        code = make_code(name, K, S, seed=0)
        assert code.name == name and (code.K, code.S) == (K, S)
        assert code.verify(), f"{name} ({K},{S}) failed certification"


@pytest.mark.parametrize("name", FAMILIES)
def test_any_R_subset_decodes_within_contract(name):
    """Exhaustive over R-subsets: exact decode, or certified-bounded for
    the partial-recovery family."""
    rng = np.random.default_rng(0)
    for K, S in _grid(name):
        code = make_code(name, K, S, seed=1)
        for alive in _alive_patterns(K, code.R):
            _check_decode_contract(code, alive, rng)


def test_partial_recovery_below_R():
    """The approx family decodes from r_min <= r < R responses within the
    certified bound; exact families refuse the same patterns."""
    rng = np.random.default_rng(2)
    for K, S in [(4, 1), (6, 2), (8, 3)]:
        code = make_code("approx", K, S, seed=0)
        exact = make_code("cyclic", K, S, seed=0)
        assert code.min_responses < code.R and code.err_bound > 0
        for r in range(code.min_responses, code.R):
            for alive in itertools.islice(_alive_patterns(K, r), 12):
                _check_decode_contract(code, alive, rng)
                with pytest.raises(ValueError, match="responses"):
                    exact.decode_vector(alive)
        # residual is non-increasing in the alive set: the r_min bound
        # certifies every accepted pattern
        worst = max(
            code.decode_error(a)
            for a in _alive_patterns(K, code.min_responses)
        )
        assert worst <= code.err_bound * (1 + 1e-6) + 1e-9


def test_exact_families_flag_and_bound():
    for name in FAMILIES:
        fam = CODE_FAMILIES[name]
        K, S = _grid(name)[-1]
        code = make_code(name, K, S, seed=0)
        assert fam.exact == code.exact
        assert (code.err_bound == 0.0) == fam.exact


@pytest.mark.parametrize("name", FAMILIES)
def test_replication_matches_support(name):
    """Storage accounting: replication == max row support; repetition
    families store S+1 partitions per ECN, MDS stores all K."""
    for K, S in _grid(name):
        code = make_code(name, K, S, seed=0)
        sizes = [len(code.support(j)) for j in range(K)]
        assert code.replication == max(sizes)
        if name in ("fractional", "cyclic", "approx"):
            assert sizes == [S + 1] * K
        elif name == "mds":
            assert code.replication == K
        elif name == "uncoded":
            assert sizes == [1] * K


def test_mds_decodes_any_superset_of_R():
    """MDS flexibility: ANY >= R alive rows decode exactly (not just the
    fastest-R patterns repetition schemes certify)."""
    code = make_code("mds", 6, 2, seed=0)
    rng = np.random.default_rng(3)
    for n_alive in range(code.R, 7):
        for alive in _alive_patterns(6, n_alive):
            _check_decode_contract(code, alive, rng)


# -------------------------------------------------------------------------
# make_code feasibility errors (satellite: clear messages, regression)
# -------------------------------------------------------------------------


def test_make_code_unknown_family_lists_known():
    with pytest.raises(ValueError, match="unknown code family 'nope'"):
        make_code("nope", 4, 1)
    with pytest.raises(ValueError, match="approx.*cyclic.*fractional"):
        make_code("reed-solomon", 4, 1)


@pytest.mark.parametrize(
    "scheme,K,S,msg",
    [
        ("fractional", 5, 1, r"'fractional' code infeasible for K=5, S=1: "
         r"needs \(S\+1\) \| K, but 2 does not divide 5"),
        ("fractional", 9, 1, r"needs \(S\+1\) \| K"),
        ("cyclic", 3, 5, r"'cyclic' code infeasible: need 0 <= S < K "
         r"\(got K=3, S=5\)"),
        ("cyclic", 4, -1, r"need 0 <= S < K"),
        ("mds", 4, 4, r"'mds' code infeasible: need 0 <= S < K"),
        ("approx", 6, 0, r"'approx' code infeasible for K=6, S=0: "
         r"partial recovery needs S >= 1"),
        ("uncoded", 4, 1, r"'uncoded' code infeasible for K=4, S=1: "
         r"uncoded tolerates no stragglers"),
    ],
)
def test_make_code_infeasible_messages(scheme, K, S, msg):
    """The regression contract: infeasible (K, S) surfaces as the
    family's uniform ValueError, never a cryptic construction failure."""
    with pytest.raises(ValueError, match=msg):
        make_code(scheme, K, S)


def test_arm_set_rejects_infeasible_arms_at_construction():
    """The controller regression contract (DESIGN.md §15): an infeasible
    (family, S, deadline) arm fails AT ARM-SET CONSTRUCTION with the
    uniform make_code message — never at trace time — and the whole set
    is pre-checked before any code is built."""
    from repro.core.coding import check_arm_set, make_arm_set

    good = ("cyclic", 1, None)
    with pytest.raises(
        ValueError,
        match=r"'approx' code infeasible for K=6, S=0: "
        r"partial recovery needs S >= 1",
    ):
        make_arm_set((good, ("approx", 0, 3e-4)), K=6)
    with pytest.raises(
        ValueError,
        match=r"'fractional' code infeasible for K=5, S=1: "
        r"needs \(S\+1\) \| K",
    ):
        check_arm_set((good, ("fractional", 1, None)), K=5)
    with pytest.raises(ValueError, match=r"'mds' code infeasible"):
        check_arm_set((good, ("mds", 6, None)), K=6)
    with pytest.raises(ValueError, match="unknown code family 'bogus'"):
        check_arm_set((good, ("bogus", 1, None)), K=6)
    with pytest.raises(ValueError, match="arm set is empty"):
        check_arm_set((), K=6)
    with pytest.raises(ValueError, match="duplicate arm"):
        check_arm_set((good, ("cyclic", 1, None)), K=6)
    with pytest.raises(ValueError, match="deadline must be positive"):
        check_arm_set((good, ("approx", 1, -1.0)), K=6)
    with pytest.raises(ValueError, match="not a \\(scheme, S, deadline\\)"):
        check_arm_set((("cyclic", 1),), K=6)
    # The happy path builds one certified code per arm, in arm order.
    codes = make_arm_set((good, ("approx", 2, 1e-3), ("mds", 1, None)), K=6)
    assert [c.name for c in codes] == ["cyclic", "approx", "mds"]
    assert all(c.verify() for c in codes)


def test_direct_builders_share_the_uniform_range_message():
    """Direct construction and the make_code registry path raise the
    SAME 'code infeasible' message for an out-of-range (K, S)."""
    from repro.core.coding import cyclic_repetition_code, mds_code

    msg = r"'cyclic' code infeasible: need 0 <= S < K \(got K=3, S=5\)"
    with pytest.raises(ValueError, match=msg):
        cyclic_repetition_code(3, 5)
    with pytest.raises(ValueError, match=msg):
        make_code("cyclic", 3, 5)
    with pytest.raises(ValueError, match=r"'mds' code infeasible"):
        mds_code(4, 4)


def test_register_family_rejects_duplicates():
    with pytest.raises(ValueError, match="duplicate code family"):
        register_family(CODE_FAMILIES["cyclic"])


def test_registry_contents():
    assert set(CODE_FAMILIES) == {
        "uncoded", "fractional", "cyclic", "mds", "approx"
    }
    for fam in CODE_FAMILIES.values():
        assert isinstance(fam, CodeFamily)


# -------------------------------------------------------------------------
# Hypothesis property section (skipped entirely when hypothesis absent,
# mirroring tests/test_coding_properties.py)
# -------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        name=st.sampled_from(FAMILIES),
        K=st.integers(3, 8),
        S=st.integers(0, 3),
        seed=st.integers(0, 2**16),
    )
    def test_property_any_R_subset_decode(name, K, S, seed):
        """Property: any feasible (family, K, S, seed) build certifies,
        and a random R-subset decodes within the family's contract."""
        if name == "uncoded":
            S = 0
        assume(_feasible(name, K, S))
        code = make_code(name, K, S, seed=seed)
        rng = np.random.default_rng(seed)
        alive = np.zeros(K, dtype=bool)
        alive[rng.choice(K, size=code.R, replace=False)] = True
        _check_decode_contract(code, alive, rng)

    @settings(max_examples=20, deadline=None)
    @given(
        K=st.integers(3, 8),
        S=st.integers(1, 3),
        seed=st.integers(0, 1000),
        data=st.data(),
    )
    def test_property_partial_recovery_bounded(K, S, seed, data):
        """Property: approx decode from any accepted sub-R pattern stays
        within the certified bound and in-support."""
        assume(S < K)
        code = make_code("approx", K, S, seed=seed)
        r = data.draw(
            st.integers(code.min_responses, code.K), label="n_alive"
        )
        rng = np.random.default_rng(seed)
        alive = np.zeros(K, dtype=bool)
        alive[rng.choice(K, size=r, replace=False)] = True
        _check_decode_contract(code, alive, rng)

    @settings(max_examples=20, deadline=None)
    @given(
        name=st.sampled_from(["fractional", "cyclic", "mds", "approx"]),
        K=st.integers(3, 8),
        S=st.integers(0, 3),
    )
    def test_property_replication_accounting(name, K, S):
        """Property: replication always equals the max support size, and
        storage never exceeds K partitions per ECN."""
        assume(_feasible(name, K, S))
        code = make_code(name, K, S, seed=0)
        sizes = [len(code.support(j)) for j in range(K)]
        assert code.replication == max(sizes) <= K
