"""Hypothesis property test: in-scan reductions == post-hoc Trace math.

The licensing property of the streaming layer (DESIGN.md §12): for ANY
`Reduction` spec, method kernel, execution tier, and cost axis, folding
the summaries into the ``lax.scan`` carry matches `reduce_trace` applied
to the materialized `Trace` of the same run to <= 1e-5. Hypothesis draws
the spec (budgets, targets, sketch geometry) and the kernel; each
example runs both paths on the same seed.

Kept separate from ``test_reductions.py`` so the deterministic tests run
even when ``hypothesis`` is absent (optional dev dependency, see
``requirements-dev.txt``).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.graph import make_network
from repro.core.problems import DATASETS, allocate
from repro.experiments import Case
from repro.methods import Reduction, get_kernel, run_batch, run_serial

ITERS = 12

# One method per driver family: coded incremental ADMM (Pallas update,
# masked mu gather), walk ADMM (no ECN layer), and a gossip baseline
# (all-agents rounds) — the three distinct step/clock structures.
METHODS = ("csI-ADMM", "W-ADMM", "DGD")


def _case(method: str, seed: int) -> Case:
    coded = method == "csI-ADMM"
    return Case(
        method=method, dataset="usps", N=5, K=6, M=36, iters=ITERS,
        seed=seed % 5,
        S=1 + seed % 2 if coded else 0,
        scheme="cyclic" if coded else "uncoded",
    )


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_property_streaming_matches_trace_reduction(data):
    method = data.draw(st.sampled_from(METHODS))
    seed = data.draw(st.integers(0, 2**16))
    spec = Reduction(
        fields=tuple(
            data.draw(
                st.sets(
                    st.sampled_from(("accuracy", "test_error", "z_err")),
                    min_size=1,
                )
            )
        ),
        x=data.draw(st.sampled_from(("sim_time", "comm_cost"))),
        budgets=tuple(
            data.draw(
                st.lists(
                    st.floats(1e-4, 10.0, allow_nan=False), max_size=3
                )
            )
        ),
        targets=tuple(
            data.draw(
                st.lists(
                    st.floats(0.01, 1.0, allow_nan=False), max_size=3
                )
            )
        ),
        quantiles=tuple(
            data.draw(
                st.lists(
                    st.floats(0.05, 1.0, allow_nan=False), max_size=3
                )
            )
        ),
        bins=data.draw(st.integers(2, 64)),
        lo=0.0,
        hi=data.draw(st.floats(0.5, 2.0)),
        final_x=data.draw(st.booleans()),
    )
    batched = data.draw(st.booleans())

    case = _case(method, seed)
    kernel = get_kernel(method)
    net = make_network(case.N, 0.5, seed=case.seed)
    prob = allocate(DATASETS[case.dataset](case.seed), case.N, case.K)
    cfg = kernel.config(case)

    trace = run_serial(kernel, prob, net, cfg, ITERS)
    ref = trace.reduce(spec)
    if batched:
        out2 = run_batch(
            kernel, [prob] * 2, [net] * 2, [cfg] * 2, ITERS,
            reductions=spec,
        )
        got = {k: v[1] for k, v in out2.items()}
    else:
        got = run_serial(kernel, prob, net, cfg, ITERS, reductions=spec)

    assert set(got) == set(ref) == set(spec.keys())
    for k in ref:
        np.testing.assert_allclose(
            got[k], ref[k], rtol=1e-5, atol=1e-5,
            err_msg=f"{method} seed={seed} key={k}",
        )
