"""Property-based tests on the distributed consensus runtime's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.distributed import ConsensusConfig, ConsensusRuntime
from repro.kernels import coded_combine
from repro.kernels.ref import coded_combine_ref


class _Quad:
    def init(self, rng):
        return {"w": jnp.zeros((3,), jnp.float32)}

    def loss(self, params, batch):
        t = batch["tokens"].astype(jnp.float32)
        row = 0.5 * jnp.sum((params["w"][None] - t) ** 2, axis=-1)
        w = batch.get("loss_weights")
        loss = row.mean() if w is None else jnp.sum(w * row)
        return loss, {"nll": loss, "moe_aux": jnp.zeros(())}


def _mesh():
    return jax.make_mesh((1, 1, 1), ("agent", "data", "model"))


@given(
    K=st.integers(2, 8),
    S=st.integers(0, 3),
    A=st.integers(1, 3),
    seed=st.integers(0, 20),
)
@settings(max_examples=40, deadline=None)
def test_property_row_weights_sum_to_one(K, S, A, seed):
    """For any alive set with >= R responders, the decode-folded row weights
    of each partition's copies sum to 1/(K*P) — i.e. the weighted backward
    computes EXACTLY the uncoded mean gradient (eq. 6 exactness)."""
    if S >= K:
        return
    cfg = ConsensusConfig(n_agents=A, K=K, S=S, scheme="cyclic" if S else "uncoded", seed=seed)
    rt = ConsensusRuntime(_Quad(), cfg, _mesh())
    code = cfg.code()
    P_rows = 2
    rows = K * (S + 1) * P_rows
    rng = np.random.default_rng(seed)
    alive = np.ones((A, K), bool)
    for a in range(A):
        if S:
            dead = rng.choice(K, size=S, replace=False)
            alive[a, dead] = False
    w = np.asarray(rt.row_weights(jnp.asarray(alive), rows))  # (A, rows)
    # per-partition weight sums: row (j, u, p) belongs to partition sup[j][u]
    sup = np.stack([code.support(j) for j in range(K)])  # (K, S+1)
    for a in range(A):
        per_part = np.zeros(K)
        wr = w[a].reshape(K, S + 1, P_rows)
        for j in range(K):
            for u in range(S + 1):
                per_part[sup[j, u]] += wr[j, u, 0]  # same weight for all p
        # decode vector solves in f64 but is applied in f32 — allow f32 noise
        np.testing.assert_allclose(
            per_part, 1.0 / (K * P_rows), rtol=1e-3, atol=1e-6
        )


@given(
    J=st.integers(1, 8),
    n=st.integers(1, 5000),
    seed=st.integers(0, 10),
)
@settings(max_examples=25, deadline=None)
def test_property_coded_combine_any_shape(J, n, seed):
    """The Pallas combine kernel handles arbitrary (J, n) via padding."""
    k1, k2 = jax.random.split(jax.random.key(seed))
    msgs = jax.random.normal(k1, (J, n), jnp.float32)
    coeffs = jax.random.normal(k2, (J,), jnp.float32)
    out = coded_combine(msgs, coeffs, block_n=256)
    ref = coded_combine_ref(msgs, coeffs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_z_update_conservation():
    """After every step, z == z_prev + (1/A) sum_a mask_a [(dx_a) - (dy_a)/rho]
    (eq. 4c) — the token update is exactly the committed agents' deltas."""
    A, K, S = 3, 3, 1
    cfg = ConsensusConfig(n_agents=A, K=K, S=S, scheme="cyclic", mode="incremental", rho=0.7)
    rt = ConsensusRuntime(_Quad(), cfg, _mesh())
    code = cfg.code()
    sup = [code.support(j) for j in range(K)]
    rng = np.random.default_rng(0)
    P_rows = 2
    distinct = rng.standard_normal((A, K, P_rows, 3)).astype(np.float32)
    rows = []
    for a in range(A):
        for j in range(K):
            for t in sup[j]:
                rows.append(distinct[a, t])
    batch = {"tokens": jnp.asarray(np.concatenate(rows)).reshape(-1, 3)}
    state = rt.init_state(jax.random.key(1))
    for _ in range(5):
        alive = jnp.asarray(np.ones((A, K), bool))
        new, _ = rt.train_step(state, batch, alive)
        dx = np.asarray(new["x"]["w"], np.float64) - np.asarray(state["x"]["w"], np.float64)
        dy = np.asarray(new["y"]["w"], np.float64) - np.asarray(state["y"]["w"], np.float64)
        expect = np.asarray(state["z"]["w"], np.float64) + (dx - dy / cfg.rho).sum(0) / A
        np.testing.assert_allclose(
            np.asarray(new["z"]["w"], np.float64), expect, rtol=1e-5, atol=1e-6
        )
        state = new
