"""Substrate tests: data pipeline, optimizers/schedules, checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_pytree, restore_step, save_pytree, save_step
from repro.core.coding import make_code
from repro.data import TokenStream, agent_token_streams, ecn_batch_indices, make_lm_batch, partition_for_code
from repro.optim import adam_init, adam_update, admm_schedule, clip_by_global_norm, sgd_update


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_token_stream_deterministic_and_disjoint():
    a, b = agent_token_streams(2, vocab=97, seed=3)
    xa = TokenStream(97, seed=3000).sample(256)
    np.testing.assert_array_equal(a.sample(256), xa)
    assert not np.array_equal(a.sample(256), b.sample(256))


def test_make_lm_batch_shift():
    s = TokenStream(257, seed=0)
    batch = make_lm_batch(s, 4, 32)
    assert batch["tokens"].shape == (4, 32)
    # labels are next tokens: tokens[t+1] == labels[t]
    np.testing.assert_array_equal(batch["tokens"][:, 1:], batch["labels"][:, :-1])


@pytest.mark.parametrize(
    "b,K,S", [(6, 1, 0), (64, 4, 1), (4096, 6, 2), (128, 3, 2), (97, 4, 0)]
)
def test_partition_supports_cover_everything(b, K, S):
    """Every partition is stored by >= S+1 ECNs (repetition), so any S
    stragglers leave at least one live copy of every partition.

    (The hypothesis-driven variant lives in ``test_substrate_properties.py``.)
    """
    if S >= K or K % (S + 1) != 0 or b < K:
        return
    scheme = "fractional" if S else "uncoded"
    code = make_code(scheme, K, S)
    boundaries, supports = partition_for_code(b, code)
    assert boundaries[-1] == (b // K) * K
    counts = np.zeros(K, dtype=int)
    for sup in supports:
        counts[sup] += 1
    assert (counts >= S + 1).all()


def test_ecn_batch_indices_cycle():
    # P=12, mu=4 -> 3 batches; cycles walk 0,4,8,0,...
    off = ecn_batch_indices(np.arange(7), P=12, mu=4)
    np.testing.assert_array_equal(off, [0, 4, 8, 0, 4, 8, 0])
    assert (off + 4 <= 12).all()


# ---------------------------------------------------------------------------
# optim
# ---------------------------------------------------------------------------


def test_admm_schedule_matches_theorem2():
    tau, gamma = admm_schedule(c_tau=0.3, c_gamma=2.0)
    for k in (1, 4, 100):
        assert float(tau(k)) == pytest.approx(0.3 * np.sqrt(k))
        assert float(gamma(k)) == pytest.approx(2.0 / np.sqrt(k))


def test_sgd_and_clip():
    params = {"w": jnp.ones((3,), jnp.float32), "b": jnp.zeros((2,), jnp.bfloat16)}
    grads = {"w": jnp.full((3,), 4.0), "b": jnp.full((2,), 3.0, jnp.bfloat16)}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    cn = np.sqrt(sum(np.sum(np.square(np.asarray(g, np.float32))) for g in jax.tree.leaves(clipped)))
    assert cn == pytest.approx(1.0, rel=1e-2)
    new = sgd_update(params, grads, 0.5)
    np.testing.assert_allclose(np.asarray(new["w"]), 1.0 - 0.5 * 4.0)
    assert new["b"].dtype == jnp.bfloat16


def test_adam_converges_quadratic():
    params = {"x": jnp.array([5.0, -3.0])}
    state = adam_init(params)
    for _ in range(300):
        grads = {"x": 2.0 * params["x"]}
        params, state = adam_update(params, grads, state, lr=0.1)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {
        "layers": {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3) / 3},
        "step": jnp.asarray(7, jnp.int32),
        "lst": [jnp.ones(2), jnp.zeros((1,), jnp.float64)],
    }
    p = os.path.join(tmp_path, "ck.npz")
    save_pytree(p, tree)
    out = load_pytree(p, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_steps_and_mismatch(tmp_path):
    d = str(tmp_path)
    tree = {"w": jnp.ones(3)}
    assert latest_step(d) is None
    save_step(d, 10, tree)
    save_step(d, 20, tree)
    assert latest_step(d) == 20
    restored, step = restore_step(d, jnp.zeros_like(tree["w"]) if False else tree)
    assert step == 20
    with pytest.raises(ValueError):
        load_pytree(os.path.join(d, "step_00000020.npz"), {"w": jnp.ones(3), "extra": jnp.ones(1)})
