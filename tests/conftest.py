"""Test configuration.

Enables float64 for the core-algorithm tests (the paper's convergence claims
are verified to tolerances below float32 resolution). Model/kernel tests
request their dtypes explicitly, so this does not affect them.

NOTE: XLA_FLAGS device-count forcing is deliberately NOT set here — smoke
tests and benchmarks must see the single real CPU device. Only
`repro/launch/dryrun.py` forces 512 placeholder devices (in its own process).
"""

import jax

jax.config.update("jax_enable_x64", True)
