"""Test configuration.

Forces 8 CPU host devices (before jax initializes) so the mesh-sharded
execution tier is exercised by the whole suite: with >1 device visible,
`run_sweep(mode="auto")` resolves to "sharded" (DESIGN.md §9), so every
engine==serial equality test doubles as a sharded-correctness test, and
`tests/test_sharded_sweep.py` pins the three tiers against each other
explicitly. An externally-set XLA_FLAGS wins (e.g. CI shards that want
the single real device). `repro/launch/dryrun.py` still forces its own
512 placeholder devices in its own process.

Enables float64 for the core-algorithm tests (the paper's convergence
claims are verified to tolerances below float32 resolution). Model and
kernel tests request their dtypes explicitly, so this does not affect
them.
"""

import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
)

import jax  # noqa: E402  (XLA_FLAGS must be set before jax initializes)

jax.config.update("jax_enable_x64", True)
