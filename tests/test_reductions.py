"""Streaming in-scan reduction tests (DESIGN.md §12).

The layer's contract: a `Reduction` folded into the scan carry equals
the post-hoc numpy reduction of the materialized `Trace` (<= 1e-5) on
every execution tier, with sharded == batched BITWISE; chunked streaming
execution is invisible in the outputs; and the results plumbing
(`run_sweep`/`reduce_mean`/`emit_rows`) consumes pre-reduced grid arrays.
Satellite regressions ride along: the vectorized `resample_runs` must be
bit-identical to the per-run searchsorted loop, integer-typed fields must
promote to float before CI math, and `_enable_compilation_cache` must
warn (not silently pass) when the cache knobs are unavailable.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.admm import ADMMConfig, Trace
from repro.core.graph import make_network
from repro.core.problems import DATASETS, allocate
from repro.experiments import (
    Case,
    Reduction,
    SweepSpec,
    get_sweep,
    mean_ci,
    reduce_mean,
    reduce_trace,
    resample_runs,
    run_sweep,
)
from repro.methods import driver, get_kernel, run_batch, run_serial, run_sharded
from repro.methods.admm import ADMMRun

ITERS = 40

FULL_SPEC = Reduction(
    fields=("accuracy", "test_error", "z_err"),
    budgets=(0.005, 0.05, 0.2),
    x="sim_time",
    targets=(0.5, 0.2),
    quantiles=(0.1, 0.5, 0.9),
    final_x=True,
)


def _admm_runs(n=3):
    probs, nets, cfgs = [], [], []
    for s in range(n):
        S = (1, 2, 0)[s % 3]
        nets.append(make_network(5, 0.5, seed=s))
        probs.append(allocate(DATASETS["usps"](s), 5, 6))
        cfgs.append(
            ADMMRun(
                ADMMConfig(
                    M=36, K=6, S=S,
                    scheme="cyclic" if S else "uncoded", seed=s,
                )
            )
        )
    return probs, nets, cfgs


def test_spec_validation():
    with pytest.raises(ValueError, match="fields"):
        Reduction(fields=("bogus",))
    with pytest.raises(ValueError, match="fields"):
        Reduction(fields=())
    with pytest.raises(ValueError, match="axis"):
        Reduction(x="iterations")
    with pytest.raises(ValueError, match="budgets"):
        Reduction(budgets=(0.0,))
    with pytest.raises(ValueError, match="quantiles"):
        Reduction(quantiles=(1.5,))
    with pytest.raises(ValueError, match="hi > lo"):
        Reduction(quantiles=(0.5,), lo=1.0, hi=1.0)
    # hashable: specs are jit cache keys
    assert hash(FULL_SPEC) == hash(dataclasses.replace(FULL_SPEC))


def test_reduce_trace_semantics():
    """Unit semantics of the numpy reference on a hand-built trace."""
    tr = Trace(
        accuracy=np.array([0.9, 0.6, 0.3, 0.1]),
        test_error=np.array([4.0, 3.0, 2.0, 1.0]),
        comm_cost=np.array([1.0, 2.0, 3.0, 4.0]),
        sim_time=np.array([1.0, 2.0, 3.0, 4.0]),
        z_err=np.array([0.9, 0.6, 0.3, 0.1]),
        final_x=np.zeros((2, 2, 1)),
        final_z=np.zeros((2, 1)),
    )
    spec = Reduction(
        fields=("accuracy",), budgets=(0.5, 2.5, 9.0),
        targets=(0.65, 0.05), quantiles=(0.5,), bins=10, lo=0.0, hi=1.0,
    )
    out = tr.reduce(spec)
    assert out["sim_time/final"] == 4.0 and out["comm_cost/final"] == 4.0
    assert out["accuracy/final"] == 0.1
    np.testing.assert_allclose(out["accuracy/mean"], 0.475)
    np.testing.assert_allclose(
        out["accuracy/var"], np.var([0.9, 0.6, 0.3, 0.1], ddof=1)
    )
    assert out["accuracy/min"] == 0.1
    # budget 0.5 precedes the first completion -> hold-first; 2.5 -> the
    # 2nd iteration's value; 9.0 past the end -> final value.
    np.testing.assert_allclose(out["accuracy/at_budget"], [0.9, 0.6, 0.1])
    # first sim_time with accuracy <= 0.65 is iteration 2 (t=2.0);
    # 0.05 is never reached.
    np.testing.assert_allclose(out["accuracy/time_to"], [2.0, np.inf])
    # median of bins {9, 6, 3, 1} in a 10-bin [0,1) sketch: bin 3 center
    np.testing.assert_allclose(out["accuracy/quantiles"], [0.35])


@pytest.mark.parametrize("x", ["sim_time", "comm_cost"])
def test_serial_streaming_matches_reduce_trace(x):
    spec = dataclasses.replace(FULL_SPEC, x=x)
    kernel = get_kernel("csI-ADMM")
    probs, nets, cfgs = _admm_runs(2)
    for p, n, c in zip(probs, nets, cfgs):
        ref = reduce_trace(spec, run_serial(kernel, p, n, c, ITERS))
        got = run_serial(kernel, p, n, c, ITERS, reductions=spec)
        assert set(got) == set(ref) == set(spec.keys())
        for k in ref:
            np.testing.assert_allclose(
                got[k], ref[k], rtol=1e-5, atol=1e-5, err_msg=k
            )


@pytest.mark.parametrize(
    "method",
    ["W-ADMM", "D-ADMM", "DGD", "EXTRA", "pI-ADMM", "cq-sI-ADMM", "I-ADMM"],
)
def test_every_kernel_streams_correctly(method):
    """Deterministic cross-kernel parity (the hypothesis property test in
    test_reductions_properties.py fuzzes the spec too, when available):
    every registered kernel family's in-scan fold matches reduce_trace
    serially AND through the batched driver, on both cost axes."""
    kernel = get_kernel(method)
    coded = method in ("pI-ADMM", "cq-sI-ADMM")
    case = Case(
        method=method, dataset="usps", N=5, K=3, M=30, iters=30, seed=1,
        S=1 if coded else 0, scheme="cyclic" if coded else "uncoded",
    )
    net = make_network(case.N, 0.5, seed=1)
    prob = allocate(DATASETS["usps"](1), case.N, case.K)
    cfg = kernel.config(case)
    tr = run_serial(kernel, prob, net, cfg, case.iters)
    for x in ("sim_time", "comm_cost"):
        spec = dataclasses.replace(FULL_SPEC, x=x)
        ref = reduce_trace(spec, tr)
        got = run_serial(kernel, prob, net, cfg, case.iters, reductions=spec)
        gb = run_batch(
            kernel, [prob] * 2, [net] * 2, [cfg] * 2, case.iters,
            reductions=spec,
        )
        for k in ref:
            np.testing.assert_allclose(
                got[k], ref[k], rtol=1e-5, atol=1e-5, err_msg=f"{x} {k}"
            )
            np.testing.assert_allclose(
                gb[k][0], ref[k], rtol=1e-5, atol=1e-5,
                err_msg=f"batch {x} {k}",
            )


def test_batched_and_sharded_streaming_agree():
    """Streaming tier contract: sharded == batched to near machine
    precision, both match the serial streaming run to 1e-5 (DESIGN.md
    §12). Unlike the materialized path's stacked metrics, the in-scan
    fold fuses with the kernel math, and XLA's fusion choices vary with
    the per-device vmap batch size — so tier agreement is last-ulp
    close, not bitwise."""
    kernel = get_kernel("csI-ADMM")
    probs, nets, cfgs = _admm_runs(3)
    b = run_batch(kernel, probs, nets, cfgs, ITERS, reductions=FULL_SPEC)
    s = run_sharded(kernel, probs, nets, cfgs, ITERS, reductions=FULL_SPEC)
    for i in range(3):
        ref = run_serial(
            kernel, probs[i], nets[i], cfgs[i], ITERS, reductions=FULL_SPEC
        )
        for k in ref:
            np.testing.assert_allclose(
                b[k][i], s[k][i], rtol=1e-12, atol=1e-12,
                err_msg=f"run{i} {k}: sharded != batched",
            )
            np.testing.assert_allclose(
                b[k][i], ref[k], rtol=1e-5, atol=1e-5,
                err_msg=f"run{i} {k}",
            )


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs a device mesh")
def test_chunked_streaming_matches_unchunked(monkeypatch):
    """R > chunk: outputs must be invariant to the chunk boundaries (and
    to the pad-by-repeat of the ragged last chunk) — to last-ulp
    tolerance, since the chunks' per-device vmap batch sizes differ and
    fusion choices move with them."""
    kernel = get_kernel("csI-ADMM")
    D = len(jax.devices())
    probs, nets, cfgs = _admm_runs(D + 2)
    whole = run_sharded(
        kernel, probs, nets, cfgs, ITERS, reductions=FULL_SPEC
    )
    # A zero budget clamps every dispatch to D runs: 2 chunks here.
    monkeypatch.setenv("REPRO_SHARD_MEM_MB", "0")
    chunked = run_sharded(
        kernel, probs, nets, cfgs, ITERS, reductions=FULL_SPEC
    )
    for k in whole:
        np.testing.assert_allclose(
            whole[k], chunked[k], rtol=1e-12, atol=1e-12, err_msg=k
        )


def test_max_statics_bound_exact_for_admm():
    """The chunked path's one-trace guarantee: the hook equals the
    prepared MU for mixed-(M, S) runs (mu = M_bar // K, no sampling)."""
    kernel = get_kernel("csI-ADMM")
    prob = allocate(DATASETS["usps"](0), 5, 3)
    net = make_network(5, 0.5, seed=0)
    for M, S, scheme in ((60, 0, "uncoded"), (60, 1, "cyclic"),
                         (120, 1, "cyclic")):
        run = ADMMRun(ADMMConfig(M=M, K=3, S=S, scheme=scheme))
        bound = kernel.max_statics_bound(prob, run, 10)
        prep = kernel.prepare(prob, net, run, 10)
        assert bound == prep.max_statics, (M, S)
    # Gossip kernels have no max_statics, so the base default holds.
    assert get_kernel("DGD").max_statics_bound(prob, None, 10) == {}


def test_sweep_streaming_all_tiers_match_materialized():
    """run_sweep(reductions=...) on the fig5-style grid equals reducing
    the materialized traces, for every execution tier."""
    spec = SweepSpec(
        "stream_smoke",
        Case(
            method="csI-ADMM", dataset="usps", N=5, K=6, M=36,
            scheme="cyclic", iters=ITERS,
        ),
        axes={"S": [0, 1, 2], "seed": [0, 1]},
        fixup=lambda c: dataclasses.replace(
            c, scheme="uncoded" if c.S == 0 else c.scheme
        ),
        reductions=FULL_SPEC,
    )
    mat = run_sweep(spec.cases(), mode="batched")
    refs = [reduce_trace(FULL_SPEC, t) for t in mat.traces]
    for mode in ("serial", "batched", "sharded"):
        res = run_sweep(spec, mode=mode)
        assert res.reduced is not None and res.traces == []
        assert res.n_dispatches == 1  # whole S x seed grid: one group
        assert set(res.reduced) == set(FULL_SPEC.keys())
        for k in res.reduced:
            assert res.reduced[k].shape[0] == len(res.cases)
            for i, ref in enumerate(refs):
                np.testing.assert_allclose(
                    res.reduced[k][i], ref[k], rtol=1e-5, atol=1e-5,
                    err_msg=f"{mode} case{i} {k}",
                )


def test_streamed_reduce_mean_and_emit_rows():
    from benchmarks.common import Rows

    from repro.experiments import emit_rows

    spec = get_sweep("fleet_frontier", iters=10, runs=2)
    res = run_sweep(spec, mode="batched")
    assert res.reduced is not None
    # plain metric name -> the "/final" readout; full keys work verbatim
    red = reduce_mean(res, by=("scheme", "S"), field="accuracy")
    assert all(r["n"] == 4 and r["mean"].shape == () for r in red.values())
    red_b = reduce_mean(res, by=("scheme",), field="accuracy/at_budget")
    assert all(r["mean"].shape == (4,) for r in red_b.values())
    with pytest.raises(KeyError, match="not in the streamed reduction"):
        reduce_mean(res, by=("S",), field="bogus")
    rows = Rows()
    out = emit_rows(
        res, rows, "sweep/fleet_frontier", ("scheme", "S"), x="sim_time"
    )
    assert len(rows.rows) == len(out) == 6
    # x is ignored in streamed mode: no resampled budget column
    assert all("sim_time_budget" not in r[2] for r in rows.rows)
    assert all("final_accuracy=" in r[2] for r in rows.rows)


def test_fleet_frontier_registry_shape():
    spec = get_sweep("fleet_frontier", iters=8, runs=1)
    assert spec.reductions is not None
    assert spec.reductions.budgets and spec.reductions.quantiles
    cases = spec.cases()
    assert len(cases) == 12
    assert {c.response for c in cases} == {"lognormal", "pareto"}
    assert {c.scheme for c in cases} == {"cyclic", "mds", "approx"}
    assert all(
        (c.deadline is not None) == (c.scheme == "approx") for c in cases
    )


def test_heavy_tailed_responses():
    """Lognormal/Pareto draws: floor respected, mean excess ~= base_hi -
    base_lo (the equal-average-compute contract), Pareto tail heavier."""
    from repro.core.timing import TimingModel

    with pytest.raises(ValueError, match="unknown response"):
        TimingModel(response="cauchy")
    draws = {}
    for resp in ("lognormal", "pareto"):
        tm = TimingModel(
            response=resp, p_straggle=0.0, base_lo=1e-4, base_hi=2e-4
        )
        t = tm.sample_ecn_times(4000, 6, np.random.default_rng(0))
        assert t.min() >= tm.base_lo
        np.testing.assert_allclose(
            t.mean() - tm.base_lo, tm.base_hi - tm.base_lo, rtol=0.15
        )
        draws[resp] = t
    assert draws["pareto"].max() > draws["lognormal"].max()


def test_resample_runs_vectorized_matches_loop():
    """Satellite parity: the batched searchsorted must be bit-identical
    to the original per-run loop, including grid-tie and hold-first
    edge cases."""
    rng = np.random.default_rng(0)
    R, iters, n_points = 7, 50, 33
    xs = np.cumsum(rng.uniform(0.01, 1.0, size=(R, iters)), axis=1)
    # plant exact ties between grid points and xs values
    grid_ref = np.linspace(0.0, xs[:, -1].min(), n_points)
    xs[0, 3] = grid_ref[5]
    xs[1, 0] = grid_ref[0]  # = 0.0 tie at the grid origin
    xs = np.sort(xs, axis=1)
    ys = rng.normal(size=(R, iters))

    grid, out = resample_runs(xs, ys, n_points)
    np.testing.assert_array_equal(grid, grid_ref)
    loop = np.empty_like(out)
    for r in range(R):
        idx = np.searchsorted(xs[r], grid, side="right") - 1
        loop[r] = ys[r][np.clip(idx, 0, iters - 1)]
    np.testing.assert_array_equal(out, loop)
    with pytest.raises(ValueError, match="must be"):
        resample_runs(xs[0], ys[0])


def test_integer_fields_promote_to_float():
    """Satellite: integer-typed metrics (unit-count comm_cost) must not
    run CI math in integer arithmetic."""
    xs = np.cumsum(np.ones((3, 10)), axis=1)
    ys = np.arange(30, dtype=np.int32).reshape(3, 10)
    _, out = resample_runs(xs, ys, 8)
    assert np.issubdtype(out.dtype, np.floating)
    mean, ci = mean_ci(np.array([[1], [2]], dtype=np.int64))
    assert np.issubdtype(mean.dtype, np.floating)
    np.testing.assert_allclose(mean, [1.5])
    assert ci[0] > 0.0


def test_compilation_cache_warns_when_unavailable(monkeypatch):
    """Satellite: the cache helper must warn once instead of silently
    swallowing a missing-knob failure."""
    import warnings

    from repro.experiments import sweep as sweep_mod

    monkeypatch.setattr(sweep_mod, "_cache_enabled", False)

    def boom(*a, **kw):
        raise ValueError("no such config option")

    monkeypatch.setattr(sweep_mod.jax.config, "update", boom)
    with pytest.warns(RuntimeWarning, match="compilation cache"):
        sweep_mod._enable_compilation_cache()
    # the flag latched: a second call neither warns nor retries
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        sweep_mod._enable_compilation_cache()


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs a device mesh")
def test_chunked_streaming_uses_single_executable(monkeypatch):
    """Dispatch-count honesty: multi-chunk streaming must reuse ONE
    jitted executable (the max_statics_bound contract) — mixed-S chunks
    reconcile under one set of statics instead of retracing per chunk."""
    driver._sharded_reduced_fn.cache_clear()
    kernel = get_kernel("csI-ADMM")
    D = len(jax.devices())
    probs, nets, cfgs = _admm_runs(D + 2)
    monkeypatch.setenv("REPRO_SHARD_MEM_MB", "0")
    run_sharded(kernel, probs, nets, cfgs, ITERS, reductions=FULL_SPEC)
    info = driver._sharded_reduced_fn.cache_info()
    assert info.currsize == 1
